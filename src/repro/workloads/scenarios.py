"""Scenario registry: named end-to-end workloads over the PIES model.

A :class:`Scenario` composes an arrival process, popularity/churn/mobility
dynamics, and an optional edge-failure schedule into a pure generator of
:class:`~repro.core.instance.PIESInstance` sequences:

* infrastructure (edge capacities) and the service-model catalog are drawn
  **once per seed** and held fixed over the horizon, so per-tick placements
  are comparable and switching costs are meaningful;
* the *population* breathes per tick: the active user count follows the
  arrival process, user attributes follow churn generations, coverage
  follows the mobility walk;
* ``edge_failure`` composes with :mod:`repro.distributed.elastic` — dead
  hosts map to dead edge clouds via :func:`recovery_plan`, whose storage is
  zeroed (nothing placeable) and whose users are re-homed to the nearest
  surviving edge on the ring, exactly the paper's service-level recovery.

Registered scenarios (``list_scenarios()``): ``steady``, ``diurnal``,
``flash_crowd``, ``mobility_churn``, ``edge_failure``, ``trace_replay``,
``trace_replay_bursty`` (the bundled real-world-style day and bursty
weekend traces under ``examples/data/``) and ``trace_replay_azure`` (a
genuinely external trace: an Azure-Functions-style per-interval
invocation excerpt, unit-normalized onto the edge slot pool).
"""
from __future__ import annotations

import dataclasses
import functools
from pathlib import Path
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.instance import (PIESInstance, draw_edge_capacities,
                                 draw_service_catalog)
from repro.distributed.elastic import ClusterState, recovery_plan

from .arrivals import (ArrivalProcess, DiurnalArrivals, MMPPArrivals,
                       PoissonArrivals, TraceArrivals)
from .population import ChurnModel, MarkovMobility, ZipfPopularity

__all__ = [
    "Scenario",
    "register_scenario",
    "get_scenario",
    "list_scenarios",
    "horizon",
]

_TAG_INFRA = 0x0C1
_TAG_CATALOG = 0x0C2


def _rng(seed: int, tag: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([int(seed), tag]))


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, seedable workload over a fixed infrastructure."""

    name: str
    arrivals: ArrivalProcess
    popularity_factory: Callable[[int], ZipfPopularity]
    churn: ChurnModel = ChurnModel()
    mobility_p_move: float = 0.0
    n_edges: int = 6
    n_services: int = 24
    max_impls: int = 4
    n_user_slots: int = 96
    n_ticks: int = 8
    delta_max: float = 10.0
    #: (tick, host) pairs: host (= edge group) dies at the start of `tick`
    #: and stays dead for the rest of the horizon.
    failure_schedule: Tuple[Tuple[int, int], ...] = ()
    devices_per_host: int = 8
    model_parallel: int = 4
    description: str = ""

    # -- static-per-seed draws (memoized: identical across the horizon) ---
    def infrastructure(self, seed: int):
        """Edge capacities ``(K, W, R)`` — §VI-B ranges, fixed per seed."""
        return tuple(a.copy() for a in _infrastructure_cached(self, int(seed)))

    def catalog(self, seed: int):
        """Service-model catalog — §VI-B ranges, fixed per seed."""
        return tuple(a.copy() for a in _catalog_cached(self, int(seed)))

    # -- failure handling -------------------------------------------------
    def dead_edges_at(self, tick: int) -> List[int]:
        """Edges dead at ``tick`` per the elastic recovery plan."""
        failed = frozenset(h for t, h in self.failure_schedule if t <= tick)
        if not failed:
            return []
        return list(_dead_edges_cached(self, failed))

    @staticmethod
    def _rehome(u_edge: np.ndarray, dead: List[int],
                n_edges: int) -> np.ndarray:
        """Move users on dead edges to the nearest surviving ring edge."""
        if not dead:
            return u_edge
        alive = np.array([e for e in range(n_edges) if e not in dead])
        if alive.size == 0:
            raise RuntimeError("all edge clouds failed; nothing to re-home to")
        # ring distance from every edge to every surviving edge
        d = np.abs(np.arange(n_edges)[:, None] - alive[None, :])
        d = np.minimum(d, n_edges - d)
        nearest = alive[np.argmin(d, axis=1)]  # [E] — identity on survivors
        return nearest[u_edge]

    # -- the generator ----------------------------------------------------
    def active_users_at(self, seed: int, tick: int) -> int:
        """Active population size: arrivals clipped to the slot pool."""
        return int(np.clip(self.arrivals.count_at(seed, tick), 1,
                           self.n_user_slots))

    def instance_at(self, seed: int, tick: int,
                    mobility_cache: Optional[np.ndarray] = None
                    ) -> PIESInstance:
        """Materialize the PIES instance at ``(seed, tick)`` — pure.

        ``mobility_cache`` optionally passes a precomputed
        ``MarkovMobility.trajectory`` ([≥tick+1, n_user_slots]) so horizon
        generation is O(T·U) instead of O(T²·U).
        """
        K, W, R = self.infrastructure(seed)
        sm_service, sm_acc, sm_k, sm_w, sm_r = self.catalog(seed)
        pop = self.popularity_factory(self.n_services)

        n_active = self.active_users_at(seed, tick)
        service, alpha, delta = self.churn.attributes_at(
            seed, tick, n_active, pop)

        mob = MarkovMobility(self.n_edges, self.mobility_p_move)
        if mobility_cache is not None:
            u_edge = mobility_cache[tick, :n_active].copy()
        elif self.mobility_p_move > 0.0:
            u_edge = mob.edges_at(seed, tick, n_active)
        else:
            u_edge = mob.home_edges(seed, n_active)

        dead = self.dead_edges_at(tick)
        u_edge = self._rehome(u_edge, dead, self.n_edges)
        R = R.copy()
        if dead:
            R[np.asarray(dead)] = 0.0  # dead edge groups place nothing

        inst = PIESInstance(
            K=K, W=W, R=R,
            sm_service=sm_service, sm_acc=sm_acc,
            sm_k=sm_k, sm_w=sm_w, sm_r=sm_r,
            u_edge=u_edge, u_service=service,
            u_alpha=alpha, u_delta=delta,
            delta_max=self.delta_max,
        )
        inst.validate()
        return inst

    def mobility_trajectory(self, seed: int,
                            n_ticks: int) -> Optional[np.ndarray]:
        """Precomputed ``instance_at`` mobility cache covering ``n_ticks``
        (None for static-coverage scenarios) — the shared helper that keeps
        horizon generation O(T·U) for every horizon consumer (``horizon``,
        sweep materialization, the serving driver)."""
        if self.mobility_p_move <= 0.0:
            return None
        mob = MarkovMobility(self.n_edges, self.mobility_p_move)
        return mob.trajectory(seed, int(n_ticks), self.n_user_slots)

    def horizon(self, seed: int,
                n_ticks: Optional[int] = None) -> List[PIESInstance]:
        """The full per-tick instance sequence for one seed."""
        T = int(n_ticks or self.n_ticks)
        cache = self.mobility_trajectory(seed, T)
        return [self.instance_at(seed, t, mobility_cache=cache)
                for t in range(T)]


# Memoized per-(scenario, seed) draws — Scenario is a frozen (hashable)
# dataclass, so a horizon of T ticks draws infrastructure/catalog once and
# re-derives the elastic recovery plan only per distinct failed-host set.

@functools.lru_cache(maxsize=512)
def _infrastructure_cached(scenario: Scenario, seed: int):
    return draw_edge_capacities(_rng(seed, _TAG_INFRA), scenario.n_edges)


@functools.lru_cache(maxsize=512)
def _catalog_cached(scenario: Scenario, seed: int):
    return draw_service_catalog(_rng(seed, _TAG_CATALOG),
                                scenario.n_services, scenario.max_impls)


@functools.lru_cache(maxsize=512)
def _dead_edges_cached(scenario: Scenario, failed: frozenset):
    from repro.distributed.elastic import plan_survivor_mesh
    healthy = ClusterState(n_hosts=scenario.n_edges,
                           devices_per_host=scenario.devices_per_host)
    data0, _ = plan_survivor_mesh(healthy, scenario.model_parallel)
    state = dataclasses.replace(healthy, failed_hosts=failed)
    plan = recovery_plan(
        state, model_parallel=scenario.model_parallel,
        global_batch=data0 * scenario.model_parallel, old_data=data0,
        edge_of_host={h: h for h in range(scenario.n_edges)})
    return tuple(plan["dead_edges"])


# ===========================================================================
# Registry
# ===========================================================================

_REGISTRY: Dict[str, Callable[[], Scenario]] = {}


def register_scenario(factory: Callable[[], Scenario]):
    """Decorator: register a zero-arg scenario factory under its name."""
    scenario = factory()
    _REGISTRY[scenario.name] = factory
    return factory


def list_scenarios() -> List[str]:
    return sorted(_REGISTRY)


def get_scenario(name: str, **overrides) -> Scenario:
    try:
        scenario = _REGISTRY[name]()
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; have {list_scenarios()}") from None
    return dataclasses.replace(scenario, **overrides) if overrides \
        else scenario


def horizon(name: str, seed: int = 0,
            n_ticks: Optional[int] = None, **overrides) -> List[PIESInstance]:
    """Convenience: ``get_scenario(name).horizon(seed, n_ticks)``."""
    return get_scenario(name, **overrides).horizon(seed, n_ticks)


# ===========================================================================
# The catalog
# ===========================================================================

@register_scenario
def steady() -> Scenario:
    """Stationary i.i.d. traffic — the paper's §VI-B setting over time."""
    return Scenario(
        name="steady",
        arrivals=PoissonArrivals(rate=64.0),
        popularity_factory=lambda s: ZipfPopularity(s, exponent=0.8),
        churn=ChurnModel(lifetime=64),
        description="Homogeneous Poisson arrivals, static Zipf popularity, "
                    "negligible churn — the stationary baseline.",
    )


@register_scenario
def diurnal() -> Scenario:
    """Day/night sinusoidal load with slow popularity drift."""
    return Scenario(
        name="diurnal",
        arrivals=DiurnalArrivals(base_rate=56.0, amplitude=0.7, period=8),
        popularity_factory=lambda s: ZipfPopularity(
            s, exponent=1.0, drift_period=4),
        churn=ChurnModel(lifetime=24),
        description="Sinusoidal arrival rate (period 8 ticks) with the "
                    "popularity hot spot rotating every 4 ticks.",
    )


@register_scenario
def flash_crowd() -> Scenario:
    """Bursty MMPP traffic with a fast-moving hot service."""
    return Scenario(
        name="flash_crowd",
        arrivals=MMPPArrivals(base_rate=36.0, burst_rate=92.0,
                              p_burst=0.4, block=2),
        popularity_factory=lambda s: ZipfPopularity(
            s, exponent=1.4, drift_period=2, drift_step=5),
        churn=ChurnModel(lifetime=8),
        description="Block-renewal MMPP bursts (2.5× base rate) while the "
                    "Zipf head jumps 5 services every 2 ticks — the "
                    "placement-churn stress test.",
    )


@register_scenario
def mobility_churn() -> Scenario:
    """Users migrate across edge clouds while the population turns over."""
    return Scenario(
        name="mobility_churn",
        arrivals=PoissonArrivals(rate=64.0),
        popularity_factory=lambda s: ZipfPopularity(s, exponent=1.0),
        churn=ChurnModel(lifetime=6),
        mobility_p_move=0.3,
        description="Ring random-walk mobility (p_move=0.3) plus fast churn "
                    "(mean lifetime 6 ticks): coverage sets mutate while "
                    "demand stays stationary in aggregate.",
    )


#: Fallback day trace (hourly counts) if examples/data/ is not shipped.
_FALLBACK_DAY_TRACE = (18, 14, 11, 9, 8, 10, 16, 27, 44, 58, 66, 72,
                       78, 74, 69, 63, 60, 65, 74, 86, 92, 81, 55, 31)

#: Fallback weekend trace (48 hourly counts, bursty: flash events jump
#: ≥ 30 requests hour-over-hour) if examples/data/ is not shipped.
_FALLBACK_WEEKEND_TRACE = (
    30, 24, 18, 13, 10, 9, 11, 15, 22, 31, 42, 55,
    90, 58, 52, 49, 53, 64, 95, 92, 88, 72, 55, 42,
    33, 26, 19, 14, 10, 8, 9, 13, 20, 30, 44, 58,
    66, 91, 93, 76, 60, 57, 84, 70, 64, 48, 33, 24)


def _bundled_trace(filename: str, fallback: Tuple[int, ...]
                   ) -> TraceArrivals:
    # registration happens at import time, so a missing/corrupt trace file
    # (partial checkout, installed package without examples/) must degrade
    # to the identical built-in counts, never break `import repro.workloads`
    path = Path(__file__).resolve().parents[3] / "examples" / "data" / \
        filename
    try:
        return TraceArrivals.from_file(path)
    except (OSError, ValueError):
        return TraceArrivals(counts=fallback)


def _bundled_day_trace() -> TraceArrivals:
    return _bundled_trace("diurnal_trace.csv", _FALLBACK_DAY_TRACE)


def _bundled_weekend_trace() -> TraceArrivals:
    return _bundled_trace("bursty_weekend_trace.csv",
                          _FALLBACK_WEEKEND_TRACE)


#: The Azure excerpt's per-tick counts after the loader's unit
#: normalization (60-minute buckets, mean 42/tick) — the fallback must
#: equal the processed file exactly so a partial checkout degrades to
#: identical counts (see _bundled_trace).
_AZURE_TARGET_MEAN = 42.0
_FALLBACK_AZURE_TRACE = (
    14, 11, 10, 11, 14, 18, 24, 33, 39, 47, 53, 59,
    55, 63, 67, 69, 66, 61, 55, 48, 40, 33, 25, 19,
    15, 12, 11, 11, 15, 20, 27, 34, 42, 51, 59, 60,
    59, 67, 75, 74, 72, 68, 71, 94, 59, 36, 28, 21)


def _bundled_azure_trace() -> TraceArrivals:
    path = Path(__file__).resolve().parents[3] / "examples" / "data" / \
        "azure_function_excerpt.csv"
    try:
        return TraceArrivals.from_azure_csv(
            path, minutes_per_tick=60, target_mean=_AZURE_TARGET_MEAN)
    except (OSError, ValueError):
        return TraceArrivals(counts=_FALLBACK_AZURE_TRACE)


@register_scenario
def trace_replay() -> Scenario:
    """Replay the bundled real-world-style day trace, tick = one hour."""
    return Scenario(
        name="trace_replay",
        arrivals=_bundled_day_trace(),
        popularity_factory=lambda s: ZipfPopularity(
            s, exponent=1.0, drift_period=12),
        churn=ChurnModel(lifetime=16),
        n_ticks=24,
        description="Exact replay of the bundled 24-hour request-count "
                    "trace (examples/data/diurnal_trace.csv): overnight "
                    "trough, lunchtime plateau, evening peak — the first "
                    "real-world-trace workload.",
    )


@register_scenario
def trace_replay_bursty() -> Scenario:
    """Replay the bundled bursty weekend trace, tick = one hour."""
    return Scenario(
        name="trace_replay_bursty",
        arrivals=_bundled_weekend_trace(),
        popularity_factory=lambda s: ZipfPopularity(
            s, exponent=1.2, drift_period=6, drift_step=3),
        churn=ChurnModel(lifetime=10),
        n_ticks=48,
        description="Exact replay of the bundled 48-hour weekend trace "
                    "(examples/data/bursty_weekend_trace.csv): flash "
                    "events jump ≥30 requests hour-over-hour while the "
                    "popularity head drifts — the second real trace, and "
                    "the bursty counterpoint the auto-tuner fits against.",
    )


@register_scenario
def trace_replay_azure() -> Scenario:
    """Replay the Azure-Functions-style excerpt, tick = one hour."""
    return Scenario(
        name="trace_replay_azure",
        arrivals=_bundled_azure_trace(),
        popularity_factory=lambda s: ZipfPopularity(
            s, exponent=1.1, drift_period=8, drift_step=2),
        churn=ChurnModel(lifetime=12),
        n_ticks=48,
        description="48-hour replay of an external Azure-Functions-style "
                    "per-interval invocation trace (examples/data/"
                    "azure_function_excerpt.csv), aggregated into hourly "
                    "ticks and mean-normalized onto the slot pool: "
                    "workday diurnal cycle, lunchtime dip, and a day-2 "
                    "evening flash event — the first genuinely external "
                    "public-trace workload, for fleet-scale sweeps.",
    )


@register_scenario
def edge_failure() -> Scenario:
    """Edge groups die mid-horizon; survivors absorb their users."""
    return Scenario(
        name="edge_failure",
        arrivals=PoissonArrivals(rate=64.0),
        popularity_factory=lambda s: ZipfPopularity(s, exponent=1.0),
        churn=ChurnModel(lifetime=32),
        failure_schedule=((3, 1), (5, 4)),
        description="Hosts 1 and 4 fail at ticks 3 and 5 (via "
                    "repro.distributed.elastic recovery_plan); their users "
                    "re-home to the nearest surviving ring edge.",
    )
