"""repro.models — the EI service implementations (data plane)."""
from .config import ModelConfig, plan_gqa_padding, GQAPadding
from . import layers, transformer
from .layers import MeshContext
from .transformer import (
    init_params, param_pspecs, forward, loss_fn, prefill, decode_step,
    init_cache, cache_spec, cache_pspecs, Cache, logits_fn,
)
