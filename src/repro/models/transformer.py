"""Model assembly: parameter init + sharding specs, train/prefill/decode.

One code path covers the whole assigned zoo via :class:`ModelConfig`:

* ``dense`` / ``audio`` / ``vlm`` — [attention → MLP] × L (scan over a
  stacked parameter pytree; per-layer attention window array realizes
  gemma2's alternating local/global pattern with a single traced body);
* ``moe``   — [attention → MoE] × L;
* ``ssm``   — [Mamba2 SSD] × L;
* ``hybrid``— Mamba2 backbone in segments with shared attention+MLP blocks
  (Zamba2-style: ``n_shared_blocks`` alternating shared parameter sets)
  applied every ``shared_attn_every`` layers.

Layers are scanned (``jax.lax.scan`` over stacked params) so the HLO holds
one traced copy of each block — essential to keep 94-layer dry-run compiles
tractable — and optionally rematerialized (``jax.checkpoint`` with
``nothing_saveable``) so only the sequence-sharded residual stream is kept
alive between layers.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .config import ModelConfig, MAMBA, ATTN_FULL, ATTN_SWA


def _remat_policy(cfg):
    if cfg.remat_policy == "save_attn":
        return jax.checkpoint_policies.save_only_these_names("attn_out")
    return jax.checkpoint_policies.nothing_saveable
from . import layers as L
from .layers import MeshContext, cst

Params = Dict[str, Any]


# ===========================================================================
# Parameter init
# ===========================================================================

def _stack_init(fn, n: int, key):
    keys = jax.random.split(key, n)
    return jax.vmap(fn)(keys)


def init_params(cfg: ModelConfig, key) -> Params:
    pdt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    D, Vp = cfg.d_model, cfg.vocab_pad
    params: Params = {}
    params["embed"] = {
        "tok": jax.random.normal(keys[0], (Vp, D), pdt) * 0.02,
    }
    if cfg.frontend == "audio":
        params["embed"]["frame_in"] = jax.random.normal(keys[5], (D, D), pdt) * 0.02
    if cfg.frontend == "vision":
        params["embed"]["patch_in"] = jax.random.normal(keys[5], (D, D), pdt) * 0.02

    kinds = cfg.layer_kinds
    n_attn = sum(1 for k in kinds if k != MAMBA)
    n_mamba = sum(1 for k in kinds if k == MAMBA)

    if cfg.family == "hybrid":
        assert n_mamba == cfg.n_layers, "hybrid backbone is all-mamba here"
        params["mamba"] = {
            "block": _stack_init(lambda k: L.init_mamba(cfg, k, pdt), n_mamba, keys[1]),
            "ln": _stack_init(lambda k: L.init_rms_norm(D, pdt), n_mamba, keys[6]),
        }
        def shared_init(k):
            k1, k2, k3, k4 = jax.random.split(k, 4)
            return {
                "ln1": L.init_rms_norm(D, pdt),
                "attn": L.init_attention(cfg, k1, pdt),
                "ln2": L.init_rms_norm(D, pdt),
                "mlp": L.init_mlp(cfg, k2, pdt),
            }
        params["shared"] = _stack_init(shared_init, cfg.n_shared_blocks, keys[2])
    elif cfg.family == "ssm":
        params["mamba"] = {
            "block": _stack_init(lambda k: L.init_mamba(cfg, k, pdt), n_mamba, keys[1]),
            "ln": _stack_init(lambda k: L.init_rms_norm(D, pdt), n_mamba, keys[6]),
        }
    else:
        def layer_init(k):
            k1, k2 = jax.random.split(k)
            lp = {
                "ln1": L.init_rms_norm(D, pdt),
                "attn": L.init_attention(cfg, k1, pdt),
                "ln2": L.init_rms_norm(D, pdt),
            }
            if cfg.n_experts:
                lp["moe"] = L.init_moe(cfg, k2, pdt)
            else:
                lp["mlp"] = L.init_mlp(cfg, k2, pdt)
            if cfg.post_norms:
                lp["ln_pa"] = L.init_rms_norm(D, pdt)
                lp["ln_pf"] = L.init_rms_norm(D, pdt)
            return lp
        params["layers"] = _stack_init(layer_init, cfg.n_layers, keys[1])

    params["final_norm"] = L.init_rms_norm(D, pdt)
    if cfg.encoder_only:
        params["head"] = jax.random.normal(keys[3], (D, Vp), pdt) * 0.02
    elif not cfg.tie_embeddings:
        params["head"] = jax.random.normal(keys[3], (D, Vp), pdt) * 0.02
    return params


# ===========================================================================
# Parameter sharding specs (FSDP over 'data', TP over 'model')
# ===========================================================================

def param_pspecs(cfg: ModelConfig, stacked: bool = True) -> Params:
    """PartitionSpec tree mirroring :func:`init_params`.

    Stacked per-layer leaves get a leading ``None`` (layer dim unsharded).
    """
    def st(*spec):
        return P(*((None,) + spec)) if stacked else P(*spec)

    attn = {"wq": st("data", "model", None), "wk": st("data", "model", None),
            "wv": st("data", "model", None), "wo": st("model", None, "data")}
    mlp = {"w_gate": st("data", "model"), "w_up": st("data", "model"),
           "w_down": st("model", "data")}
    norm = {"scale": st(None)}
    specs: Params = {"embed": {"tok": P("model", "data")}}
    if cfg.frontend == "audio":
        specs["embed"]["frame_in"] = P("data", "model")
    if cfg.frontend == "vision":
        specs["embed"]["patch_in"] = P("data", "model")

    mamba = {
        "in_proj": st("data", "model"), "conv_w": st(None, "model"),
        "conv_b": st("model"), "A_log": st(None), "D_skip": st(None),
        "dt_bias": st(None), "norm_scale": st("model"),
        "out_proj": st("model", "data"),
    }
    if cfg.family in ("hybrid", "ssm"):
        specs["mamba"] = {"block": mamba, "ln": norm}
        if cfg.family == "hybrid":
            specs["shared"] = {"ln1": norm, "attn": {k: st(*v[1:]) if False else v
                                                     for k, v in attn.items()},
                               "ln2": norm, "mlp": mlp}
            # shared blocks are stacked over n_shared_blocks too
            specs["shared"] = {
                "ln1": {"scale": P(None, None)},
                "attn": {"wq": P(None, "data", "model", None),
                         "wk": P(None, "data", "model", None),
                         "wv": P(None, "data", "model", None),
                         "wo": P(None, "model", None, "data")},
                "ln2": {"scale": P(None, None)},
                "mlp": {"w_gate": P(None, "data", "model"),
                        "w_up": P(None, "data", "model"),
                        "w_down": P(None, "model", "data")},
            }
    else:
        lp = {"ln1": norm, "attn": attn, "ln2": norm}
        if cfg.n_experts:
            if cfg.n_experts % max(cfg.tp_shards, 1) == 0:
                lp["moe"] = {"router": st(None, None),
                             "w_gate": st("model", "data", None),
                             "w_up": st("model", "data", None),
                             "w_down": st("model", None, "data")}
            else:
                lp["moe"] = {"router": st(None, None),
                             "w_gate": st(None, "data", "model"),
                             "w_up": st(None, "data", "model"),
                             "w_down": st(None, "model", "data")}
        else:
            lp["mlp"] = mlp
        if cfg.post_norms:
            lp["ln_pa"] = norm
            lp["ln_pf"] = norm
        specs["layers"] = lp

    specs["final_norm"] = {"scale": P(None)}
    if "head" in _head_keys(cfg):
        specs["head"] = P("data", "model")
    return specs


def _head_keys(cfg: ModelConfig):
    return {"head"} if (cfg.encoder_only or not cfg.tie_embeddings) else set()


def retarget_fsdp(spec_tree, fsdp_axes):
    """Replace the 'data' (FSDP) axis in a pspec tree with e.g.
    ('pod', 'data') so optimizer state shards across pods too (ZeRO over
    the full DP domain instead of within-pod only)."""
    if fsdp_axes == "data":
        return spec_tree

    def fix(spec):
        return P(*[fsdp_axes if a == "data" else a for a in spec])

    return jax.tree_util.tree_map(
        fix, spec_tree, is_leaf=lambda x: isinstance(x, P))


# ===========================================================================
# Embedding / head
# ===========================================================================

def embed_tokens(params: Params, cfg: ModelConfig, tokens, ctx):
    """Token embedding. With a mesh, the vocab-sharded table is looked up
    inside shard_map (local masked gather + psum over the model axis) —
    avoids XLA's one-hot lowering of sharded gathers, which materializes a
    [B, S, V/shards] temp (tens of GB for 256k vocabs)."""
    dt = jnp.dtype(cfg.dtype)
    emb = params["embed"]["tok"]
    small = tokens.shape[0] * tokens.shape[1] <= 4096  # decode-sized: plain take
    if ctx is None or small or tokens.shape[0] % ctx.data_size != 0:
        x = jnp.take(emb.astype(dt), tokens, axis=0)
    else:
        from jax.experimental.shard_map import shard_map

        m, fs = ctx.model_axis, ctx.fsdp_axes
        Vp = cfg.vocab_pad
        v_local = Vp // ctx.model_size

        def body(tok, table):
            table = jax.lax.all_gather(table.astype(dt), fs, axis=1,
                                       tiled=True)  # [V/m, D]
            lo = jax.lax.axis_index(m) * v_local
            local = tok - lo
            ok = (local >= 0) & (local < v_local)
            safe = jnp.clip(local, 0, v_local - 1)
            out = jnp.take(table, safe, axis=0) * ok[..., None].astype(dt)
            return jax.lax.psum(out, m)

        x = shard_map(
            body, mesh=ctx.mesh,
            in_specs=(P(ctx.batch_axes, None), P(m, fs)),
            out_specs=P(ctx.batch_axes, None, None),
            check_rep=False,
        )(tokens, emb)
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    return x


def embed_input(params: Params, cfg: ModelConfig, batch: Dict[str, Any], ctx):
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio":
        x = L.dense(batch["frames"].astype(dt), params["embed"]["frame_in"], dt)
    elif cfg.frontend == "vision":
        px = L.dense(batch["patches"].astype(dt), params["embed"]["patch_in"], dt)
        tx = embed_tokens(params, cfg, batch["tokens"], ctx)
        x = jnp.concatenate([px, tx], axis=1)
    else:
        x = embed_tokens(params, cfg, batch["tokens"], ctx)
    return cst(ctx, x, "batch", "model" if (ctx and ctx.shard_seq) else None, None)


def logits_fn(params: Params, cfg: ModelConfig, x, ctx):
    dt = jnp.dtype(cfg.dtype)
    x = L.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    w = params.get("head", None)
    if w is None:
        w = params["embed"]["tok"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(dt)).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    # mask padded vocab slots
    if cfg.vocab_pad != cfg.vocab_size:
        neg = jnp.full((cfg.vocab_pad - cfg.vocab_size,), -1e30, jnp.float32)
        logits = logits.at[..., cfg.vocab_size:].add(neg)
    return logits


# ===========================================================================
# Layer stacks
# ===========================================================================

def _window_array(cfg: ModelConfig) -> np.ndarray:
    """Per-layer attention window (0 = full) for attention layers in order."""
    wins = [cfg.window if k == ATTN_SWA else 0
            for k in cfg.layer_kinds if k != MAMBA]
    return np.asarray(wins, np.int32)


def _attn_layer_body(cfg, ctx, positions, kv_len, ring):
    def body(x, lp, window, kv):
        h = L.rms_norm(x, lp["ln1"]["scale"], cfg.norm_eps)
        a, new_kv = L.attention_block(
            lp["attn"], cfg, h, positions, ctx=ctx, window=window,
            kv_cache=kv, kv_len=kv_len, ring=ring)
        if cfg.post_norms:
            a = L.rms_norm(a, lp["ln_pa"]["scale"], cfg.norm_eps)
        x = x + a
        h = L.rms_norm(x, lp["ln2"]["scale"], cfg.norm_eps)
        if cfg.n_experts:
            f = L.moe_block(lp["moe"], cfg, h, ctx=ctx)
        else:
            f = L.mlp_block(lp["mlp"], cfg, h, ctx=ctx)
        if cfg.post_norms:
            f = L.rms_norm(f, lp["ln_pf"]["scale"], cfg.norm_eps)
        return x + f, new_kv
    return body


def _mamba_layer_body(cfg, ctx):
    def body(x, lp, cache):
        h = L.rms_norm(x, lp["ln"]["scale"], cfg.norm_eps)
        m, new_cache = L.mamba_block(lp["block"], cfg, h, ctx=ctx, cache=cache)
        return x + m, new_cache
    return body


def run_attention_stack(params: Params, cfg: ModelConfig, x, positions, ctx,
                        cache=None, kv_len=None, ring=False):
    """Scan over stacked [attention → FFN] layers. cache: (K, V) stacked
    [L, B, Sc, KVp, hd] or None. Returns (x, new_cache)."""
    windows = jnp.asarray(_window_array(cfg))
    body = _attn_layer_body(cfg, ctx, positions, kv_len, ring)
    fn = jax.checkpoint(body, policy=_remat_policy(cfg)) \
        if cfg.remat else body

    if cache is None:
        def scan_nocache(carry, scanned):
            lp, window = scanned
            x_new, _ = fn(carry, lp, window, None)
            return x_new, None
        x, _ = jax.lax.scan(scan_nocache, x, (params["layers"], windows))
        return x, None

    def scan_withcache(carry, scanned):
        lp, window, ck, cv = scanned
        x_new, new_kv = fn(carry, lp, window, (ck, cv))
        return x_new, new_kv

    x, (nk, nv) = jax.lax.scan(
        scan_withcache, x, (params["layers"], windows, cache[0], cache[1]))
    return x, (nk, nv)


def run_mamba_stack(params: Params, cfg: ModelConfig, x, ctx, cache=None):
    """Scan over Mamba2 layers. cache: (conv [L,B,cw-1,ch], ssm [L,B,H,P,N])."""
    body = _mamba_layer_body(cfg, ctx)

    def scan_body(carry, scanned):
        lp, cc = scanned
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
            if cfg.remat else body
        x_new, new_cache = fn(carry, lp, cc)
        return x_new, new_cache

    mp = {"block": params["block"], "ln": params["ln"]}
    stacked = jax.tree_util.tree_map(lambda a: a, mp)
    if cache is None:
        def nocache(carry, lp):
            fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable) \
                if cfg.remat else body
            x_new, _ = fn(carry, lp, None)
            return x_new, None
        x, _ = jax.lax.scan(
            nocache, x, {"block": params["block"], "ln": params["ln"]})
        return x, None
    conv, ssm = cache
    def withcache(carry, scanned):
        lp = {"block": scanned[0], "ln": scanned[1]}
        return scan_body(carry, (lp, (scanned[2], scanned[3])))
    x, (nc, ns) = jax.lax.scan(
        withcache, x, (params["block"], params["ln"], conv, ssm))
    return x, (nc, ns)


def run_hybrid_stack(params: Params, cfg: ModelConfig, x, positions, ctx,
                     cache=None, kv_len=None):
    """Zamba2-style: segments of Mamba layers + shared attention blocks.

    The shared block after segment ``i`` uses shared parameter set
    ``i % n_shared_blocks`` (tree-selected inside the scan body).
    """
    k = cfg.shared_attn_every
    n_seg = cfg.n_layers // k
    shared = params["shared"]
    body_m = _mamba_layer_body(cfg, ctx)

    def seg_reshape(a):
        return a.reshape((n_seg, k) + a.shape[1:])

    mamba_seg = jax.tree_util.tree_map(seg_reshape, params["mamba"])

    def select_shared(i):
        idx = i % cfg.n_shared_blocks
        return jax.tree_util.tree_map(lambda a: a[idx], shared)

    def shared_body(x, sp, kv):
        h = L.rms_norm(x, sp["ln1"]["scale"], cfg.norm_eps)
        a, new_kv = L.attention_block(
            sp["attn"], cfg, h, positions, ctx=ctx, window=0,
            kv_cache=kv, kv_len=kv_len)
        x = x + a
        h = L.rms_norm(x, sp["ln2"]["scale"], cfg.norm_eps)
        x = x + L.mlp_block(sp["mlp"], cfg, h, ctx=ctx)
        return x, new_kv

    def seg_scan(carry, scanned):
        x = carry
        seg_idx = scanned["idx"]
        # inner: k mamba layers
        def inner(c, s):
            lp = {"block": s[0], "ln": s[1]}
            fn = jax.checkpoint(body_m, policy=jax.checkpoint_policies.nothing_saveable) \
                if cfg.remat else body_m
            if "conv" in scanned:
                xn, nc = fn(c, lp, (s[2], s[3]))
                return xn, nc
            xn, _ = fn(c, lp, None)
            return xn, None
        if "conv" in scanned:
            xs = (scanned["mamba"]["block"], scanned["mamba"]["ln"],
                  scanned["conv"], scanned["ssm"])
        else:
            xs = (scanned["mamba"]["block"], scanned["mamba"]["ln"])
        x, mcache = jax.lax.scan(inner, x, xs)
        sp = select_shared(seg_idx)
        kv = (scanned["sk"], scanned["sv"]) if "sk" in scanned else None
        fn_s = jax.checkpoint(shared_body, policy=jax.checkpoint_policies.nothing_saveable) \
            if cfg.remat else shared_body
        x, new_kv = fn_s(x, sp, kv)
        out = {}
        if mcache is not None and "conv" in scanned:
            out["conv"], out["ssm"] = mcache
        if new_kv is not None and "sk" in scanned:
            out["sk"], out["sv"] = new_kv
        return x, out

    xs = {"idx": jnp.arange(n_seg), "mamba": mamba_seg}
    if cache is not None:
        conv, ssm, sk, sv = cache
        xs["conv"] = conv.reshape((n_seg, k) + conv.shape[1:])
        xs["ssm"] = ssm.reshape((n_seg, k) + ssm.shape[1:])
        xs["sk"], xs["sv"] = sk, sv
    x, outs = jax.lax.scan(seg_scan, x, xs)
    if cache is None:
        return x, None
    nconv = outs["conv"].reshape((-1,) + outs["conv"].shape[2:])
    nssm = outs["ssm"].reshape((-1,) + outs["ssm"].shape[2:])
    return x, (nconv, nssm, outs["sk"], outs["sv"])


# ===========================================================================
# Forward passes
# ===========================================================================

def forward(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            ctx: Optional[MeshContext] = None):
    """Full-sequence forward (training / encoding). Returns final hidden."""
    x = embed_input(params, cfg, batch, ctx)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.family in ("ssm",):
        x, _ = run_mamba_stack(params["mamba"], cfg, x, ctx)
    elif cfg.family == "hybrid":
        x, _ = run_hybrid_stack(params, cfg, x, positions, ctx)
    else:
        x, _ = run_attention_stack(params, cfg, x, positions, ctx)
    return x


def softmax_xent(params, cfg, x, targets, mask, ctx, chunk: int = 512):
    """Cross-entropy over (possibly huge, padded) vocab, chunked over seq so
    [B, chunk, V] logits never exceed a bounded working set."""
    B, S, D = x.shape
    # chunk whenever the full [B, S, V] logits tensor is big (≥16k vocab):
    # §Perf iteration 6 — full-logit CE at smollm/49k vocab costs ~0.8 GiB
    # f32 per device in fwd and again in the rematerialized bwd.
    if cfg.vocab_pad <= 16384 or S <= chunk:
        logits = logits_fn(params, cfg, x, ctx)
        return _xent_from_logits(logits, targets, mask)

    nch = -(-S // chunk)
    pad = nch * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    xc = x.reshape(B, nch, chunk, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, nch, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, nch, chunk).transpose(1, 0, 2)

    def one(chunk_in):
        xb, tb, mb = chunk_in
        logits = logits_fn(params, cfg, xb, ctx)
        l, m = _xent_from_logits(logits, tb, mb, reduce=False)
        return l, m

    fn = jax.checkpoint(one, policy=jax.checkpoint_policies.nothing_saveable)
    losses, masses = jax.lax.map(fn, (xc, tc, mc))
    return losses.sum() / jnp.maximum(masses.sum(), 1.0)


def _xent_from_logits(logits, targets, mask, reduce: bool = True):
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - tgt) * mask
    if reduce:
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.sum(), mask.sum()


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            ctx: Optional[MeshContext] = None):
    x = forward(params, cfg, batch, ctx)
    mask = batch.get("mask")
    targets = batch["targets"]
    if mask is None:
        mask = jnp.ones(targets.shape, jnp.float32)
    return softmax_xent(params, cfg, x, targets, mask.astype(jnp.float32), ctx)


# ===========================================================================
# KV/state cache
# ===========================================================================

class Cache(NamedTuple):
    """Decode-time state. Unused fields hold zero-size arrays (pytree-stable)."""
    kv_k: Any       # [L_attn, B, Sc, KVp, hd]
    kv_v: Any
    conv: Any       # [L_mamba, B, cw-1, conv_ch]
    ssm: Any        # [L_mamba, B, H, P, N]  (float32)
    pos: Any        # [B] int32 — next position to write


def cache_spec(cfg: ModelConfig, batch: int, max_seq: int
               ) -> Tuple[Dict[str, tuple], bool]:
    """Shapes/dtypes for the cache; returns (spec, ring)."""
    dt = cfg.dtype
    kinds = cfg.layer_kinds
    n_attn = sum(1 for k in kinds if k != MAMBA)
    n_mamba = sum(1 for k in kinds if k == MAMBA)
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.shared_attn_every
        n_mamba = cfg.n_layers
    ring = n_attn > 0 and all(k == ATTN_SWA for k in kinds if k != MAMBA) \
        and cfg.window < max_seq and cfg.family != "hybrid"
    Sc = cfg.window if ring else max_seq
    pad = cfg.gqa
    spec = {
        "kv_k": ((n_attn, batch, Sc, pad.n_kv_pad, cfg.head_dim), dt),
        "kv_v": ((n_attn, batch, Sc, pad.n_kv_pad, cfg.head_dim), dt),
        "conv": ((n_mamba, batch, max(cfg.conv_width - 1, 0),
                  cfg.d_inner + 2 * cfg.ssm_state if n_mamba else 0), dt),
        "ssm": ((n_mamba, batch, cfg.ssm_heads if n_mamba else 0,
                 cfg.ssm_head_dim, cfg.ssm_state), "float32"),
        "pos": ((batch,), "int32"),
    }
    return spec, ring


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> Tuple[Cache, bool]:
    spec, ring = cache_spec(cfg, batch, max_seq)
    return Cache(**{k: jnp.zeros(s, jnp.dtype(d))
                    for k, (s, d) in spec.items()}), ring


def cache_pspecs(cfg: ModelConfig) -> Cache:
    """Sharding: batch over data axes; padded KV heads over model."""
    return Cache(
        kv_k=P(None, "data", None, "model", None),
        kv_v=P(None, "data", None, "model", None),
        conv=P(None, "data", None, "model"),
        ssm=P(None, "data", "model", None, None),
        pos=P("data"),
    )


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            cache: Cache, ring: bool, ctx: Optional[MeshContext] = None
            ) -> Tuple[Any, Cache]:
    """Run the prompt through the model, filling the cache.
    Returns (last-position logits [B, Vp], cache)."""
    x = embed_input(params, cfg, batch, ctx)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    kv_len = jnp.full((B,), S, jnp.int32)
    if cfg.family == "ssm":
        x, mc = run_mamba_stack(params["mamba"], cfg, x, ctx,
                                cache=(cache.conv, cache.ssm))
        new = cache._replace(conv=mc[0], ssm=mc[1], pos=cache.pos + S)
    elif cfg.family == "hybrid":
        x, hc = run_hybrid_stack(params, cfg, x, positions, ctx,
                                 cache=(cache.conv, cache.ssm,
                                        cache.kv_k, cache.kv_v),
                                 kv_len=kv_len)
        new = cache._replace(conv=hc[0], ssm=hc[1], kv_k=hc[2], kv_v=hc[3],
                             pos=cache.pos + S)
    else:
        x, kv = run_attention_stack(params, cfg, x, positions, ctx,
                                    cache=(cache.kv_k, cache.kv_v),
                                    kv_len=kv_len, ring=ring)
        new = cache._replace(kv_k=kv[0], kv_v=kv[1], pos=cache.pos + S)
    logits = logits_fn(params, cfg, x[:, -1:], ctx)[:, 0]
    return logits, new


def decode_step(params: Params, cfg: ModelConfig, token, cache: Cache,
                ring: bool, ctx: Optional[MeshContext] = None
                ) -> Tuple[Any, Cache]:
    """One decode step. token: [B] int32. Returns (logits [B, Vp], cache)."""
    x = embed_tokens(params, cfg, token[:, None], ctx)
    B = x.shape[0]
    positions = cache.pos[:, None]
    kv_len = cache.pos + 1
    if cfg.family == "ssm":
        x, mc = run_mamba_stack(params["mamba"], cfg, x, ctx,
                                cache=(cache.conv, cache.ssm))
        new = cache._replace(conv=mc[0], ssm=mc[1], pos=cache.pos + 1)
    elif cfg.family == "hybrid":
        x, hc = run_hybrid_stack(params, cfg, x, positions, ctx,
                                 cache=(cache.conv, cache.ssm,
                                        cache.kv_k, cache.kv_v),
                                 kv_len=kv_len)
        new = cache._replace(conv=hc[0], ssm=hc[1], kv_k=hc[2], kv_v=hc[3],
                             pos=cache.pos + 1)
    else:
        x, kv = run_attention_stack(params, cfg, x, positions, ctx,
                                    cache=(cache.kv_k, cache.kv_v),
                                    kv_len=kv_len, ring=ring)
        new = cache._replace(kv_k=kv[0], kv_v=kv[1], pos=cache.pos + 1)
    logits = logits_fn(params, cfg, x, ctx)[:, 0]
    return logits, new
