"""Weight-only int8 quantization — implementation variants for PIES.

The paper's core premise is that one service has *multiple implementations
with different cost/QoS trade-offs*. Quantization manufactures exactly
that: every architecture yields an int8 variant with ~2× smaller storage
(= the paper's ``r_sm``), faster weight transfer/load, and a small
accuracy delta — a second point on the accuracy/cost frontier from the
same checkpoint.

Per-output-channel symmetric int8:

    w_q[o, :] = round(w[o, :] / s_o),  s_o = max|w[o, :]| / 127

Storage is int8 + one f32 scale per output channel; serving dequantizes at
load (bf16 compute — weight-only quantization, the standard LLM serving
recipe). ``agreement`` measures top-1 logit agreement vs the bf16 model on
probe prompts, which the catalog uses to derive the variant's ``A_sm``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["quantize_tree", "dequantize_tree", "quantized_bytes",
           "logit_agreement"]

#: leaves smaller than this stay unquantized (norm scales, biases)
_MIN_SIZE = 4096


def _quantize_leaf(w):
    if w.ndim < 2 or w.size < _MIN_SIZE or not jnp.issubdtype(
            w.dtype, jnp.floating):
        return w, None
    wf = w.astype(jnp.float32)
    # per-leading-channel scales over all remaining axes
    axes = tuple(range(1, w.ndim))
    s = jnp.max(jnp.abs(wf), axis=axes, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(wf / s), -127, 127).astype(jnp.int8)
    return q, s


def quantize_tree(params) -> Tuple[Any, Any]:
    """Returns (quantized_tree, scales_tree). Unquantized leaves have a
    ``None`` scale and pass through unchanged."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    qs, ss = [], []
    for w in leaves:
        q, s = _quantize_leaf(w)
        qs.append(q)
        ss.append(s)
    return (jax.tree_util.tree_unflatten(treedef, qs),
            jax.tree_util.tree_unflatten(treedef, ss))


def dequantize_tree(qtree, stree, dtype=jnp.bfloat16):
    """Rebuild compute weights (bf16) from the int8 storage form."""
    def deq(q, s):
        if s is None:
            return q
        return (q.astype(jnp.float32) * s).astype(dtype)

    return jax.tree_util.tree_map(
        deq, qtree, stree,
        is_leaf=lambda x: x is None or hasattr(x, "dtype"))


def quantized_bytes(qtree, stree) -> int:
    """Storage footprint of the quantized form (int8 + scales)."""
    total = 0
    for q, s in zip(jax.tree_util.tree_leaves(qtree),
                    jax.tree_util.tree_leaves(stree, is_leaf=lambda x: x is None)):
        total += q.size * q.dtype.itemsize
        if s is not None:
            total += s.size * 4
    return total


def logit_agreement(cfg, params_ref, params_q, n_probes: int = 8,
                    seq: int = 32, seed: int = 0) -> float:
    """Top-1 next-token agreement between the reference and quantized
    models on random probe prompts — the accuracy-delta proxy the serving
    catalog uses for the variant's A_sm."""
    from repro.models import transformer as T

    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (n_probes, seq)))
    batch = {"tokens": toks}
    xr = T.forward(params_ref, cfg, batch, None)
    xq = T.forward(params_q, cfg, batch, None)
    lr = T.logits_fn(params_ref, cfg, xr, None)[..., : cfg.vocab_size]
    lq = T.logits_fn(params_q, cfg, xq, None)[..., : cfg.vocab_size]
    return float((jnp.argmax(lr, -1) == jnp.argmax(lq, -1)).mean())
