"""Functional model layers (pure JAX, explicit parameter pytrees).

Everything here is shape-polymorphic and mesh-aware but *mesh-optional*:
pass ``mesh_ctx=None`` for single-device smoke tests, or a
:class:`MeshContext` for pjit/shard_map distribution. Attention follows a
chunked flash formulation (never materializes S×S for long sequences) and
doubles as the reference oracle for the Pallas kernels in
``repro.kernels``; MoE uses sort-based capacity dispatch inside
``shard_map`` (expert × d_ff factorization of the model axis); Mamba2 uses
the chunked SSD (state-space duality) algorithm — matmul-rich intra-chunk
work for the MXU, tiny inter-chunk recurrence.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .config import ModelConfig

Params = Dict[str, Any]


# ===========================================================================
# Mesh context & sharding helpers
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class MeshContext:
    """Carries the mesh and logical-axis mapping through the model."""
    mesh: Any                       # jax.sharding.Mesh
    batch_axes: Tuple[str, ...]     # e.g. ("data",) or ("pod", "data")
    model_axis: str = "model"
    shard_seq: bool = True          # sequence-parallel residual stream
    #: route dense projections through shard_map with the sequence
    #: all-gather inside the differentiated region: forward gathers a
    #: 1/TP-sized shard instead of all-reducing a full partial sum, and
    #: the backward of the gather is a reduce-scatter (Megatron-SP).
    #: Baseline (False) relies on XLA SPMD, which emits full all-reduces
    #: for partial-sum matmuls — see EXPERIMENTS.md §Perf.
    sp_matmuls: bool = False

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def fsdp_axes(self):
        """Axes the FSDP (ZeRO-3) domain spans — the full DP domain."""
        return self.batch_axes

    @property
    def data_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    def constraint(self, x, spec: P):
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec))


def cst(ctx: Optional[MeshContext], x, *axes):
    """Apply a sharding constraint when a mesh is present; no-op otherwise.

    ``axes`` entries: "batch" → ctx.batch_axes, "model" → model axis,
    None → unsharded.
    """
    if ctx is None:
        return x
    spec = []
    for a in axes:
        if a == "batch":
            spec.append(ctx.batch_axes)
        elif a == "model":
            spec.append(ctx.model_axis)
        else:
            spec.append(None)
    return ctx.constraint(x, P(*spec))


# ===========================================================================
# Primitives
# ===========================================================================

def rms_norm(x, scale, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int, dtype) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}


def dense(x, w, dtype):
    return jnp.einsum("...d,df->...f", x, w.astype(dtype))


def _rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S] int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(_rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., :, None].astype(jnp.float32)[..., None, :] * freqs  # [...,S,1,hd/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True)}[name]


# ===========================================================================
# Attention (chunked flash, GQA via padded uniform groups)
# ===========================================================================

def init_attention(cfg: ModelConfig, key, dtype) -> Params:
    pad = cfg.gqa
    D, hd = cfg.d_model, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = 0.02

    def head_pad_init(k, n_slots, slot_to_orig):
        w = jax.random.normal(k, (D, n_slots, hd), dtype) * std
        mask = jnp.asarray([1.0 if o >= 0 else 0.0 for o in slot_to_orig], dtype)
        return w * mask[None, :, None]

    wq = head_pad_init(k1, pad.n_q_pad, pad.q_slot_to_q)
    wk = head_pad_init(k2, pad.n_kv_pad, pad.kv_slot_to_kv)
    wv = head_pad_init(k3, pad.n_kv_pad, pad.kv_slot_to_kv)
    wo = jax.random.normal(k4, (pad.n_q_pad, hd, D), dtype) * std
    womask = jnp.asarray([1.0 if o >= 0 else 0.0 for o in pad.q_slot_to_q], dtype)
    wo = wo * womask[:, None, None]
    return {"wq": wq, "wk": wk, "wv": wv, "wo": wo}


def _attn_weights_tied(params: Params, pad) -> Params:
    """Tie padded duplicate KV slots to their original-head weights so the
    padded model is numerically identical to the logical one. (Duplicated
    kv slots share initial weights; during training gradients differ per
    copy which is mathematically a reparameterization — for exactness tests
    we tie at init only.)"""
    return params


def flash_attention_jnp(q, k, v, q_pos, kv_pos, *, causal: bool, window: int,
                        attn_softcap: float, kv_valid_len=None,
                        q_chunk: int = 1024, kv_chunk: int = 1024):
    """Chunked (flash) attention — the reference oracle for the Pallas kernel.

    q: [B, Sq, Hq, hd] — Hq padded query heads (uniform groups)
    k, v: [B, Skv, Hkv, hd] — padded KV slots; group = Hq // Hkv
    q_pos: [B, Sq] absolute positions; kv_pos: [B, Skv]
    window: 0 ⇒ full attention, else sliding window (causal assumed)
    kv_valid_len: [B] — entries at kv index ≥ valid_len are masked (cache)

    Never materializes [Sq, Skv] for the full sequence: scans q chunks
    (outer) × kv chunks (inner) with running (max, sum, acc).
    """
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    nq, nk = -(-Sq // qc), -(-Skv // kc)
    # pad seq dims to chunk multiples
    def pad_to(x, n, axis):
        padw = [(0, 0)] * x.ndim
        padw[axis] = (0, n - x.shape[axis])
        return jnp.pad(x, padw) if n != x.shape[axis] else x
    qp = pad_to(q, nq * qc, 1)
    kp = pad_to(k, nk * kc, 1)
    vp = pad_to(v, nk * kc, 1)
    qpos = pad_to(q_pos, nq * qc, 1)
    kpos = pad_to(kv_pos, nk * kc, 1)
    kv_len = kv_valid_len if kv_valid_len is not None else jnp.full((B,), Skv, jnp.int32)

    # [B, nq, qc, Hkv, G, hd]
    qg = qp.reshape(B, nq, qc, Hkv, G, hd)
    kg = kp.reshape(B, nk, kc, Hkv, hd)
    vg = vp.reshape(B, nk, kc, Hkv, hd)
    qposc = qpos.reshape(B, nq, qc)
    kposc = kpos.reshape(B, nk, kc)

    def q_block(qi):
        # transpose q to the score layout ONCE per q block — inside the kv
        # step the einsum would re-transpose it per chunk (§Perf: ~2 TB of
        # transpose traffic at qwen3/train_4k)
        qb = qg[:, qi].transpose(0, 2, 3, 1, 4)     # [B, Hkv, G, qc, hd]
        qpb = qposc[:, qi]        # [B, qc]

        def kv_step(carry, ki):
            m, l, acc = carry
            kb, vb = kg[:, ki], vg[:, ki]           # [B, kc, Hkv, hd]
            kpb = kposc[:, ki]                      # [B, kc]
            qpb_ = qpb
            s = jnp.einsum("bkgqh,bskh->bkgqs", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if attn_softcap:
                s = attn_softcap * jnp.tanh(s / attn_softcap)
            # mask: causal, window, cache validity
            dq = qpb_[:, None, None, :, None]       # [B,1,1,qc,1]
            dk = kpb[:, None, None, None, :]        # [B,1,1,1,kc]
            ok = jnp.ones_like(s, dtype=bool)
            if causal:
                ok &= dk <= dq
            # window may be a traced per-layer scalar; 0 ⇒ full attention
            win = jnp.asarray(window, jnp.int32)
            lo = jnp.where(win > 0, dq - win, jnp.int32(-(2 ** 30)))
            ok &= dk > lo
            ok &= (jnp.arange(kc)[None, :] + ki * kc
                   < kv_len[:, None])[:, None, None, None, :]
            s = jnp.where(ok, s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows (m_new = -inf): exp(-inf - -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qc, hd), jnp.float32)
        # checkpoint the kv step: backward recomputes the [qc, kc] score /
        # prob tiles from (q, k) instead of saving them for every chunk
        # pair — the flash-attention backward. Without this the saved
        # tiles are O(S²) and defeat the chunking entirely.
        kv_step_ck = jax.checkpoint(
            kv_step, policy=jax.checkpoint_policies.nothing_saveable)
        (m, l, acc), _ = jax.lax.scan(kv_step_ck, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # [B,Hkv,G,qc,hd]
        return out.transpose(0, 3, 1, 2, 4)                 # [B,qc,Hkv,G,hd]

    outs = jax.lax.map(q_block, jnp.arange(nq))             # [nq,B,qc,Hkv,G,hd]
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qc, Hq, hd)
    return out[:, :Sq].astype(q.dtype)


def decode_attention_jnp(q, k_cache, v_cache, kv_len, *, window: int,
                         attn_softcap: float, ring: bool = False):
    """Single-token attention against a KV cache.

    q: [B, Hq, hd]; k_cache/v_cache: [B, Sc, Hkv, hd]; kv_len: [B] number of
    valid cache entries (= current absolute position + 1). With ``ring``
    the cache is a ring buffer of size ``window`` (SWA): absolute position
    of slot j is recovered from kv_len.
    """
    B, Sc, Hkv, hd = k_cache.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    # Chunked online-softmax decode read (the jnp twin of the Pallas
    # gqa_decode kernel): the cache is streamed in kv blocks with f32
    # running (max, sum, acc). Monolithic formulations (one big matvec or
    # mul-reduce over the full 32k cache) trip XLA-CPU float
    # normalization into materializing f32 copies of the whole cache —
    # chunking keeps any legalization cast at block granularity
    # (§Perf iteration 1).
    blk = min(2048, Sc)
    nk = -(-Sc // blk)
    pad = nk * blk - Sc
    kc_ = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k_cache
    vc_ = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v_cache
    scale = 1.0 / math.sqrt(hd)
    win = jnp.asarray(window, jnp.int32)

    def kv_step(j, carry):
        m_prev, l_prev, acc = carry
        # dynamic_slice chunk reads (no transposed cache copy)
        kb = jax.lax.dynamic_slice_in_dim(kc_, j * blk, blk, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vc_, j * blk, blk, axis=1)
        sb = jnp.einsum("bkgh,bskh->bkgs", qg, kb,
                        preferred_element_type=jnp.float32) * scale
        if attn_softcap:
            sb = attn_softcap * jnp.tanh(sb / attn_softcap)
        idx = j * blk + jnp.arange(blk)[None, :]        # [1, blk]
        if ring:
            valid = ((idx < kv_len[:, None]) | (kv_len[:, None] > Sc)) \
                & (idx < Sc)
        else:
            valid = (idx < kv_len[:, None]) & (idx < Sc)
            lo = jnp.where(win > 0, kv_len[:, None] - 1 - win,
                           jnp.int32(-(2 ** 30)))
            valid &= idx > lo
        sb = jnp.where(valid[:, None, None, :], sb, -1e30)
        m_new = jnp.maximum(m_prev, sb.max(-1))
        m_safe = jnp.maximum(m_new, -1e20)
        p = jnp.exp(sb - m_safe[..., None])
        corr = jnp.exp(jnp.maximum(m_prev, -1e20) - m_safe) \
            * (m_prev > -5e29).astype(jnp.float32)
        l_new = l_prev * corr + p.sum(-1)
        pv = jnp.einsum("bkgs,bskh->bkgh", p.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        return (m_new, l_new, acc * corr[..., None] + pv)

    m0 = jnp.full((B, Hkv, G), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, kv_step, (m0, l0, a0))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Hq, hd).astype(q.dtype)


def attention_block(params: Params, cfg: ModelConfig, x, positions, *,
                    ctx: Optional[MeshContext], window: int,
                    kv_cache: Optional[Tuple] = None, kv_len=None,
                    ring: bool = False, d_model: Optional[int] = None):
    """Full attention sub-block: qkv proj → rope → flash/decode → out proj.

    Returns (out, new_kv) where new_kv is (k, v) to store when caching.
    x: [B, S, D]; decode when S == 1 and kv_cache is not None.
    """
    dt = jnp.dtype(cfg.dtype)
    pad = cfg.gqa
    if _sp_sharded(ctx, x):
        # train AND prefill: q/k/v computed identically; prefill writes the
        # SP-produced k/v into the cache below
        x = cst(ctx, x, "batch", "model", None)       # seq-sharded in
        q, k, v = sp_qkv(ctx, cfg, x, params["wq"], params["wk"],
                         params["wv"])
    else:
        x = cst(ctx, x, "batch", None, None)  # gather seq for attention
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
        q = cst(ctx, q, "batch", None, "model", None)
        k = cst(ctx, k, "batch", None, "model", None)
        v = cst(ctx, v, "batch", None, "model", None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is None:
        o = flash_attention_jnp(
            q, k, v, positions, positions, causal=cfg.causal,
            window=window, attn_softcap=cfg.attn_softcap)
        from jax.ad_checkpoint import checkpoint_name
        o = checkpoint_name(o, "attn_out")
        new_kv = (k, v)
    else:
        ck, cv = kv_cache
        if q.shape[1] == 1:  # decode: write then attend
            B, Sc = ck.shape[0], ck.shape[1]
            # Static batching: decode positions are uniform across the
            # batch, so the cache write is ONE dynamic_update_slice at a
            # scalar step index. (A vmapped per-row DUS lowers to scatter,
            # and XLA-CPU legalizes bf16 scatter through f32 — which made
            # the layer scan carry f32 shadow copies of the whole cache:
            # ~2 TB/step at yi-34B/32k. §Perf iteration 1.) Ragged
            # positions (continuous batching) use the Pallas decode kernel
            # on TPU, which writes per-row natively.
            slot = (positions[0, 0] % Sc) if ring else positions[0, 0]
            ck = jax.lax.dynamic_update_slice(ck, k, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v, (0, slot, 0, 0))
            # barrier: XLA commutes the f32 accumulation cast onto the
            # cache operand (convert(mul(..)) → mul(convert(..))) and then
            # promotes the whole scanned cache carry to f32; the barrier
            # pins the cast at slice granularity (§Perf iteration 1).
            ck_use, cv_use = jax.lax.optimization_barrier((ck, cv))
            o = decode_attention_jnp(
                q[:, 0], ck_use, cv_use, kv_len, window=window,
                attn_softcap=cfg.attn_softcap, ring=ring)[:, None]
        else:                 # prefill into cache
            B, S = q.shape[:2]
            Sc = ck.shape[1]
            if ring and S > Sc:
                kw, vw = k[:, -Sc:], v[:, -Sc:]
                # ring layout: slot j = pos % Sc
                roll = (positions[:, -Sc:][:, 0]) % Sc
                kw = jax.vmap(lambda a, r: jnp.roll(a, r, axis=0))(kw, roll)
                vw = jax.vmap(lambda a, r: jnp.roll(a, r, axis=0))(vw, roll)
                ck, cv = kw, vw
            else:
                ck = jax.lax.dynamic_update_slice(ck, k, (0, 0, 0, 0))
                cv = jax.lax.dynamic_update_slice(cv, v, (0, 0, 0, 0))
            o = flash_attention_jnp(
                q, k, v, positions, positions, causal=cfg.causal,
                window=window, attn_softcap=cfg.attn_softcap)
        new_kv = (ck, cv)

    if _sp_sharded(ctx, o):
        out = sp_out_proj(ctx, cfg, o, params["wo"])
    else:
        o = cst(ctx, o, "batch", None, "model", None)
        out = jnp.einsum("bshk,hkd->bsd", o, params["wo"].astype(dt))
        out = cst(ctx, out, "batch",
                  "model" if (ctx and ctx.shard_seq) else None, None)
    return out, new_kv


# ===========================================================================
# Dense MLP (SwiGLU / GeLU)
# ===========================================================================

def init_mlp(cfg: ModelConfig, key, dtype) -> Params:
    D, F = cfg.d_model, cfg.d_ff_pad
    k1, k2, k3 = jax.random.split(key, 3)
    std = 0.02
    return {
        "w_gate": jax.random.normal(k1, (D, F), dtype) * std,
        "w_up": jax.random.normal(k2, (D, F), dtype) * std,
        "w_down": jax.random.normal(k3, (F, D), dtype) * std,
    }


def mlp_block(params: Params, cfg: ModelConfig, x, *, ctx: Optional[MeshContext]):
    dt = jnp.dtype(cfg.dtype)
    if _sp_sharded(ctx, x):
        x = cst(ctx, x, "batch", "model", None)
        return sp_mlp(ctx, cfg, x, params["w_gate"], params["w_up"],
                      params["w_down"])
    x = cst(ctx, x, "batch", None, None)
    g = dense(x, params["w_gate"], dt)
    u = dense(x, params["w_up"], dt)
    g = cst(ctx, g, "batch", None, "model")
    u = cst(ctx, u, "batch", None, "model")
    h = _act(cfg.act)(g) * u
    out = dense(h, params["w_down"], dt)
    out = cst(ctx, out, "batch", "model" if (ctx and ctx.shard_seq) else None, None)
    return out


# ===========================================================================
# Sequence-parallel (Megatron-SP) projection paths — shard_map
# ===========================================================================

def _sp_sharded(ctx, x) -> bool:
    """SP path applies when tokens are shardable over (batch × seq).
    x: [B, S, D] activations or [B, S, Hp, hd] attention outputs."""
    return (ctx is not None and ctx.sp_matmuls and x.ndim in (3, 4)
            and x.shape[1] > 1
            and x.shape[0] % ctx.data_size == 0
            and x.shape[1] % ctx.model_size == 0)


def sp_qkv(ctx: MeshContext, cfg: ModelConfig, x, wq, wk, wv):
    """x: [B, S, D] seq-sharded → (q, k, v) head-sharded. The seq
    all-gather lives inside the differentiated region, so its transpose is
    a reduce-scatter (vs the baseline's full dx all-reduce)."""
    from jax.experimental.shard_map import shard_map

    dt = jnp.dtype(cfg.dtype)
    m, fs, b = ctx.model_axis, ctx.fsdp_axes, ctx.batch_axes

    def body(xl, wql, wkl, wvl):
        xg = jax.lax.all_gather(xl, m, axis=1, tiled=True)
        wq_ = jax.lax.all_gather(wql.astype(dt), fs, axis=0, tiled=True)
        wk_ = jax.lax.all_gather(wkl.astype(dt), fs, axis=0, tiled=True)
        wv_ = jax.lax.all_gather(wvl.astype(dt), fs, axis=0, tiled=True)
        q = jnp.einsum("bsd,dhk->bshk", xg, wq_)
        k = jnp.einsum("bsd,dhk->bshk", xg, wk_)
        v = jnp.einsum("bsd,dhk->bshk", xg, wv_)
        return q, k, v

    hspec = P(b, None, m, None)
    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(b, m, None), P(fs, m, None), P(fs, m, None),
                  P(fs, m, None)),
        out_specs=(hspec, hspec, hspec), check_rep=False)(x, wq, wk, wv)


def sp_out_proj(ctx: MeshContext, cfg: ModelConfig, o, wo):
    """o: [B, S, Hp, hd] head-sharded → residual delta seq-sharded via an
    explicit psum_scatter (baseline: full all-reduce + reshard)."""
    from jax.experimental.shard_map import shard_map

    dt = jnp.dtype(cfg.dtype)
    m, fs, b = ctx.model_axis, ctx.fsdp_axes, ctx.batch_axes

    def body(ol, wol):
        wo_ = jax.lax.all_gather(wol.astype(dt), fs, axis=2, tiled=True)
        part = jnp.einsum("bshk,hkd->bsd", ol, wo_)
        return jax.lax.psum_scatter(part, m, scatter_dimension=1, tiled=True)

    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(b, None, m, None), P(m, None, fs)),
        out_specs=P(b, m, None), check_rep=False)(o, wo)


def sp_mlp(ctx: MeshContext, cfg: ModelConfig, x, wg, wu, wd):
    """Fused SP MLP: gather seq once, TP over d_ff, psum_scatter out."""
    from jax.experimental.shard_map import shard_map

    dt = jnp.dtype(cfg.dtype)
    m, fs, b = ctx.model_axis, ctx.fsdp_axes, ctx.batch_axes
    act = _act(cfg.act)

    def body(xl, wgl, wul, wdl):
        xg = jax.lax.all_gather(xl, m, axis=1, tiled=True)
        wg_ = jax.lax.all_gather(wgl.astype(dt), fs, axis=0, tiled=True)
        wu_ = jax.lax.all_gather(wul.astype(dt), fs, axis=0, tiled=True)
        wd_ = jax.lax.all_gather(wdl.astype(dt), fs, axis=1, tiled=True)
        h = act(jnp.einsum("bsd,df->bsf", xg, wg_)) \
            * jnp.einsum("bsd,df->bsf", xg, wu_)
        part = jnp.einsum("bsf,fd->bsd", h, wd_)
        return jax.lax.psum_scatter(part, m, scatter_dimension=1, tiled=True)

    return shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(b, m, None), P(fs, m), P(fs, m), P(m, fs)),
        out_specs=P(b, m, None), check_rep=False)(x, wg, wu, wd)


# ===========================================================================
# MoE (capacity-based, sort dispatch, shard_map expert×ff parallel)
# ===========================================================================

def _moe_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    """Per-expert capacity. Decode-sized batches (≤256 assignment slots)
    get lossless capacity so no token is ever dropped while generating;
    training/prefill use the standard capacity-factor rule."""
    if n_tokens * cfg.top_k <= 256:
        return n_tokens * cfg.top_k
    return max(1, int(n_tokens * cfg.top_k * cfg.capacity_factor
                      // cfg.n_experts))


def init_moe(cfg: ModelConfig, key, dtype) -> Params:
    D, F, E = cfg.d_model, cfg.d_ff_pad, cfg.n_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    std = 0.02
    return {
        "router": jax.random.normal(k0, (D, E), jnp.float32) * std,
        "w_gate": jax.random.normal(k1, (E, D, F), dtype) * std,
        "w_up": jax.random.normal(k2, (E, D, F), dtype) * std,
        "w_down": jax.random.normal(k3, (E, F, D), dtype) * std,
    }


def _moe_local(x, gate_w, up_w, down_w, router, cfg: ModelConfig,
               e0: int, n_local: int, capacity: int):
    """Route local tokens to local experts [e0, e0+n_local) and compute.

    x: [T, D]. Returns the (partial) output [T, D] — caller psums across
    expert/ff shards. Sort-based dispatch: no one-hot dispatch einsums, so
    HLO FLOPs stay proportional to *active* expert compute.
    """
    T, Dm = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = x.dtype
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                  # [T, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = idx.reshape(-1)                              # [T*k]
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e)                           # stable
    se, st = flat_e[order], flat_t[order]
    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[se]
    local = (se >= e0) & (se < e0 + n_local) & (pos < capacity)
    n_slots = n_local * capacity
    slot = jnp.where(local, (se - e0) * capacity + pos, n_slots)

    # Dispatch/combine are pure GATHERS; the only scatters are 1-D int32
    # slot maps (XLA's scatter expander materializes update-shaped index
    # matrices — [T·k, D]-sized scatters cost ~16 GiB of temps at 4k·256).
    slot_token = jnp.zeros((n_slots + 1,), jnp.int32).at[slot].set(st)
    slot_valid = jnp.zeros((n_slots + 1,), jnp.bool_).at[slot].set(local)
    xb = x[slot_token[:-1]] * slot_valid[:-1, None].astype(dt)
    xb = xb.reshape(n_local, capacity, Dm)
    g = jnp.einsum("ecd,edf->ecf", xb, gate_w.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xb, up_w.astype(dt))
    h = _act(cfg.act)(g) * u
    yb = jnp.einsum("ecf,efd->ecd", h, down_w.astype(dt)).reshape(-1, Dm)
    yb = jnp.concatenate([yb, jnp.zeros((1, Dm), dt)], axis=0)

    # combine: k gathers in original assignment order, summed
    inv = jnp.argsort(order)                              # [T*k]
    yslot = slot[inv].reshape(T, k)                       # slot per (t, j)
    gweight = gate.astype(dt) * local[inv].reshape(T, k).astype(dt)
    out = jnp.zeros((T, Dm), dt)
    for j in range(k):
        out = out + yb[yslot[:, j]] * gweight[:, j:j + 1]
    return out


def moe_block(params: Params, cfg: ModelConfig, x, *, ctx: Optional[MeshContext]):
    """MoE FFN. x: [B, S, D]. Tokens sharded over batch axes. Two modes:

    * **ep** (``n_experts % model_size == 0``): experts sharded over the
      model axis (expert parallelism); each model shard builds capacity
      batches only for its experts; outputs psum over the model axis.
    * **tp** (otherwise, e.g. mixtral's 8 experts on 16 shards): every
      shard holds all experts but only a d_ff slice (tensor parallelism
      within experts); partial down-projections psum over the model axis.

    Both modes FSDP the d_model dimension over the data axis and all-gather
    it inside the shard_map body (one gather per layer, overlapped by XLA
    with the previous layer under scan).
    """
    B, S, D = x.shape
    dt = jnp.dtype(cfg.dtype)

    if ctx is None:
        capacity = _moe_capacity(B * S, cfg)
        out = _moe_local(
            x.reshape(-1, D), params["w_gate"], params["w_up"],
            params["w_down"], params["router"], cfg, 0, cfg.n_experts,
            capacity)
        return out.reshape(B, S, D)

    from jax.experimental.shard_map import shard_map

    mesh = ctx.mesh
    msize = ctx.model_size
    ep_mode = cfg.n_experts % msize == 0
    n_local = cfg.n_experts // msize if ep_mode else cfg.n_experts
    T_local = (B * S) // ctx.data_size if B % ctx.data_size == 0 else B * S
    capacity = _moe_capacity(T_local, cfg)

    m, fs = ctx.model_axis, ctx.fsdp_axes
    # decode-sized batches (B < data shards) cannot shard tokens: run the
    # routing replicated over the data axes (trivial work per step)
    shardable = B % ctx.data_size == 0
    sp = ctx.sp_matmuls and shardable and S % msize == 0

    def body(xl, router, gw, uw, dw):
        # xl: [B/ddp, S, D] — replicated over the model axis.
        # Cast to compute dtype BEFORE the FSDP gather (halves gather bytes).
        gw = jax.lax.all_gather(gw.astype(dt), fs, axis=1, tiled=True)
        uw = jax.lax.all_gather(uw.astype(dt), fs, axis=1, tiled=True)
        dw = jax.lax.all_gather(dw.astype(dt), fs, axis=2, tiled=True)
        e0 = jax.lax.axis_index(m) * n_local if ep_mode else 0
        out = _moe_local(xl.reshape(-1, D), gw, uw, dw, router,
                         cfg, e0, n_local, capacity)
        out = out.reshape(xl.shape)
        if sp:
            # SP: combine expert partial sums straight into the seq-sharded
            # residual — 1/TP the operand bytes of a full all-reduce
            return jax.lax.psum_scatter(out, m, scatter_dimension=1,
                                        tiled=True)
        return jax.lax.psum(out, m)

    bspec = P(ctx.batch_axes, None, None) if shardable else P(None, None, None)
    ospec = P(ctx.batch_axes, m, None) if sp else bspec
    if ep_mode:
        gu_spec = P(m, fs, None)      # [E, D, F] — experts over model
        dn_spec = P(m, None, fs)      # [E, F, D]
    else:
        gu_spec = P(None, fs, m)      # [E, D, F] — d_ff over model
        dn_spec = P(None, m, fs)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(bspec, P(None, None), gu_spec, gu_spec, dn_spec),
        out_specs=ospec,
        check_rep=False,
    )(x, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return cst(ctx, out, "batch", "model" if ctx.shard_seq else None, None)


# ===========================================================================
# Mamba2 (SSD — state-space duality, chunked)
# ===========================================================================

def init_mamba(cfg: ModelConfig, key, dtype) -> Params:
    D = cfg.d_model
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = din + 2 * N
    d_in_proj = 2 * din + 2 * N + H
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = 0.02
    return {
        "in_proj": jax.random.normal(k1, (D, d_in_proj), dtype) * std,
        "conv_w": jax.random.normal(k2, (cfg.conv_width, conv_ch), dtype) * std,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.asarray(
            np.log(np.expm1(np.linspace(1e-3, 0.1, H))), jnp.float32),
        "norm_scale": jnp.zeros((din,), dtype),
        "out_proj": jax.random.normal(k4, (din, D), dtype) * std,
    }


def _segsum(x):
    """x: [..., T] → lower-triangular pairwise cumulative sums [..., T, T]."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(X, dtA, B, C, chunk: int, initial_state=None):
    """Chunked SSD scan (Mamba2 Alg. from arXiv:2405.21060, jnp).

    X:   [b, l, h, p]   (already multiplied by Δ)
    dtA: [b, l, h]      (Δ·A, negative)
    B,C: [b, l, n]      (single group, broadcast over heads)
    Returns (Y [b, l, h, p], final_state [b, h, p, n]).
    """
    b, l, h, p = X.shape
    n = B.shape[-1]
    nc = l // chunk
    Xc = X.reshape(b, nc, chunk, h, p)
    Ac = dtA.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)  # [b,h,c,q]
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)
    A_cum = jnp.cumsum(Ac, axis=-1)                          # [b,h,c,q]

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(Ac))                                 # [b,h,c,q,q]
    Y_diag = jnp.einsum("bcqn,bcsn,bhcqs,bcshp->bcqhp",
                        Cc, Bc, L, Xc)

    # 2. chunk-final states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)          # [b,h,c,q]
    states = jnp.einsum("bcsn,bhcs,bcshp->bchpn", Bc, decay_states, Xc)

    # 3. inter-chunk recurrence (tiny scan over chunk dim)
    chunk_decay = jnp.exp(A_cum[..., -1])                    # [b,h,c]

    def step(carry, inp):
        s_prev = carry
        s_new, dec = inp
        s = s_prev * dec[..., None, None] + s_new
        return s, s_prev

    s0 = initial_state if initial_state is not None else \
        jnp.zeros((b, h, p, n), X.dtype)
    st_seq = states.transpose(1, 0, 2, 3, 4)                 # [c,b,h,p,n]
    dec_seq = chunk_decay.transpose(2, 0, 1)                 # [c,b,h]
    final, prev_states = jax.lax.scan(step, s0, (st_seq, dec_seq))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # [b,c,h,p,n]

    # 4. inter-chunk output
    state_decay = jnp.exp(A_cum)                             # [b,h,c,q]
    Y_off = jnp.einsum("bcqn,bchpn,bhcq->bcqhp", Cc, prev_states, state_decay)
    Y = (Y_diag + Y_off).reshape(b, l, h, p)
    return Y, final


def ssd_decode_step(x, dtA, B, C, state):
    """One-token SSD recurrence. x: [b,h,p], dtA: [b,h], B/C: [b,n]."""
    decay = jnp.exp(dtA)[..., None, None]                    # [b,h,1,1]
    state = state * decay + jnp.einsum("bn,bhp->bhpn", B, x)
    y = jnp.einsum("bn,bhpn->bhp", C, state)
    return y, state


def mamba_block(params: Params, cfg: ModelConfig, x, *,
                ctx: Optional[MeshContext],
                cache: Optional[Tuple] = None):
    """Mamba2 block. x: [B, S, D]. cache = (conv_state [B, cw-1, ch],
    ssm_state [B, H, P, N]) for decode; None for train/prefill.

    Returns (out, new_cache).
    """
    dt_ = jnp.dtype(cfg.dtype)
    Bsz, S, D = x.shape
    din, N, H, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    conv_ch = din + 2 * N
    x = cst(ctx, x, "batch", None, None)

    zxbcdt = dense(x, params["in_proj"], dt_)
    z, xBC, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * N], axis=-1)
    z = cst(ctx, z, "batch", None, "model")
    xBC = cst(ctx, xBC, "batch", None, "model")
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # [B,S,H]
    dt = cst(ctx, dt, "batch", None, "model")

    cw = cfg.conv_width
    if cache is None:
        xpad = jnp.pad(xBC, ((0, 0), (cw - 1, 0), (0, 0)))
        new_conv = xpad[:, -(cw - 1):] if cw > 1 else None
    else:
        conv_state = cache[0]
        xpad = jnp.concatenate([conv_state.astype(dt_), xBC], axis=1)
        new_conv = xpad[:, -(cw - 1):] if cw > 1 else None
    # depthwise causal conv width cw
    conv = sum(xpad[:, i:i + S] * params["conv_w"][i].astype(dt_)[None, None]
               for i in range(cw))
    xBC = jax.nn.silu(conv + params["conv_b"].astype(dt_))

    xin, Bmat, Cmat = jnp.split(xBC, [din, din + N], axis=-1)
    xin = xin.reshape(Bsz, S, H, hp)
    xin = cst(ctx, xin, "batch", None, "model", None)
    # B/C are shared across SSM heads: replicate over the model axis so the
    # SSD einsums stay local per head shard (no per-chunk collectives).
    Bmat = cst(ctx, Bmat, "batch", None, None)
    Cmat = cst(ctx, Cmat, "batch", None, None)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))        # [H]
    dtA = dt * A                                              # [B,S,H]
    Xd = xin * dt.astype(dt_)[..., None]

    if cache is None or S > 1:
        pad = (-S) % cfg.ssm_chunk
        if pad:
            Xp = jnp.pad(Xd, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Ap = jnp.pad(dtA, ((0, 0), (0, pad), (0, 0)))
            Bp = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
            Cp = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        else:
            Xp, Ap, Bp, Cp = Xd, dtA, Bmat, Cmat
        init = cache[1].astype(jnp.float32) if cache is not None else None
        Y, final_state = ssd_chunked(
            Xp.astype(jnp.float32), Ap,
            Bp.astype(jnp.float32), Cp.astype(jnp.float32),
            cfg.ssm_chunk, initial_state=init)
        Y = Y[:, :S]
    else:
        y1, final_state = ssd_decode_step(
            Xd[:, 0].astype(jnp.float32), dtA[:, 0],
            Bmat[:, 0].astype(jnp.float32), Cmat[:, 0].astype(jnp.float32),
            cache[1].astype(jnp.float32))
        Y = y1[:, None]

    Y = Y.astype(dt_) + xin * params["D_skip"].astype(dt_)[None, None, :, None]
    Y = Y.reshape(Bsz, S, din)
    Y = rms_norm(Y * jax.nn.silu(z), params["norm_scale"], cfg.norm_eps)
    out = dense(Y, params["out_proj"], dt_)
    out = cst(ctx, out, "batch", "model" if (ctx and ctx.shard_seq) else None, None)
    new_cache = (new_conv.astype(dt_) if new_conv is not None else
                 jnp.zeros((Bsz, 0, conv_ch), dt_),
                 final_state.astype(jnp.float32))
    return out, new_cache
