"""Model configuration for the assigned architecture zoo.

One :class:`ModelConfig` describes any member of the LM family used here:
dense GQA transformers (llama-style, gemma2-style with alternating
local/global attention and logit softcaps), capacity-based MoE, Mamba2 SSD
stacks, Zamba2-style hybrids (Mamba backbone + shared attention blocks),
encoder-only audio backbones and VLM backbones with stub frontends.

Mesh-divisibility padding
-------------------------
The production mesh fixes the tensor-parallel axis at 16 shards. Published
head counts / vocab sizes are not always divisible by 16 (yi: 56Q/8KV,
smollm: 15Q/5KV, internvl2: 14Q/2KV, qwen3: 4KV, hubert vocab 504, mamba2
vocab 50280). Following standard practice (Megatron padded-vocab), we pad
to divisible *physical* shapes with provably-inert dummy slices and keep
the *logical* config exactly as published. :func:`plan_gqa_padding` builds
a padded head layout in which every padded query head maps to a padded
KV slot holding a copy of its original KV head, so attention outputs are
bit-identical to the unpadded model (tests/test_padding.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

__all__ = ["ModelConfig", "GQAPadding", "plan_gqa_padding", "pad_to_multiple"]


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class GQAPadding:
    """Padded attention-head layout for a tensor-parallel degree.

    ``q_slot_to_q[i]``  — original query head for padded q slot i (−1 ⇒ dummy)
    ``q_slot_to_kv[i]`` — padded KV slot attended by padded q slot i
    ``kv_slot_to_kv[j]``— original KV head copied into padded kv slot j (−1 ⇒ dummy)
    """
    n_q: int            # original query heads
    n_kv: int           # original KV heads
    n_q_pad: int        # padded query heads (multiple of shards)
    n_kv_pad: int       # padded KV heads (multiple of shards)
    group: int          # uniform padded group size = n_q_pad // n_kv_pad
    q_slot_to_q: Tuple[int, ...]
    q_slot_to_kv: Tuple[int, ...]
    kv_slot_to_kv: Tuple[int, ...]

    @property
    def is_identity(self) -> bool:
        return self.n_q == self.n_q_pad and self.n_kv == self.n_kv_pad


def plan_gqa_padding(n_q: int, n_kv: int, shards: int) -> GQAPadding:
    """Pad (n_q, n_kv) heads so both are divisible by ``shards`` and the
    padded grouping is uniform while preserving the original q→kv map.

    Strategy: pad KV heads to ``n_kv_pad = max(shards, n_kv rounded up)``
    by replicating each original KV head ``rep_i`` times (Σ rep_i covers the
    padded slots); choose uniform group ``G = ceil(g / min_i rep_i)`` with
    ``g = n_q // n_kv`` so each original group of g query heads fits into
    the padded slots pointing at copies of its KV head.
    """
    assert n_q % n_kv == 0, "published GQA configs have uniform groups"
    g = n_q // n_kv
    if n_q % shards == 0 and n_kv % shards == 0:
        ident = GQAPadding(
            n_q, n_kv, n_q, n_kv, g,
            tuple(range(n_q)),
            tuple(i // g for i in range(n_q)),
            tuple(range(n_kv)),
        )
        return ident

    n_kv_pad = pad_to_multiple(max(n_kv, shards), shards) if n_kv < shards \
        else pad_to_multiple(n_kv, shards)
    # distribute padded kv slots over original kv heads as evenly as possible
    base, extra = divmod(n_kv_pad, n_kv)
    reps = [base + (1 if i < extra else 0) for i in range(n_kv)]
    min_rep = min(reps)
    G = math.ceil(g / min_rep)
    n_q_pad = n_kv_pad * G
    # round q padding up to shard multiple too (n_kv_pad is a multiple of
    # shards, so n_q_pad already is as well)
    assert n_q_pad % shards == 0

    kv_slot_to_kv = []
    for i, r in enumerate(reps):
        kv_slot_to_kv.extend([i] * r)
    q_slot_to_q = [-1] * n_q_pad
    q_slot_to_kv = [slot // G for slot in range(n_q_pad)]
    # place original q heads: group i's g query heads go into the q slots of
    # the padded kv slots that copy original kv head i
    slots_of_kv = {}
    for slot, kv in enumerate(kv_slot_to_kv):
        slots_of_kv.setdefault(kv, []).append(slot)
    for kv in range(n_kv):
        q_heads = list(range(kv * g, (kv + 1) * g))
        cursor = 0
        for kv_slot in slots_of_kv[kv]:
            for j in range(G):
                if cursor < len(q_heads):
                    q_slot_to_q[kv_slot * G + j] = q_heads[cursor]
                    cursor += 1
        assert cursor == len(q_heads), "padding plan failed to place q heads"
    pad = GQAPadding(n_q, n_kv, n_q_pad, n_kv_pad, G,
                     tuple(q_slot_to_q), tuple(q_slot_to_kv),
                     tuple(kv_slot_to_kv))
    _validate_padding(pad)
    return pad


def _validate_padding(p: GQAPadding) -> None:
    g = p.n_q // p.n_kv
    placed = [q for q in p.q_slot_to_q if q >= 0]
    assert sorted(placed) == list(range(p.n_q)), "every q head placed once"
    for slot, q in enumerate(p.q_slot_to_q):
        if q >= 0:
            kv_slot = p.q_slot_to_kv[slot]
            assert p.kv_slot_to_kv[kv_slot] == q // g, \
                "padded q slot must see a copy of its original KV head"


# layer kinds used by the block pattern
ATTN_FULL = "full"
ATTN_SWA = "swa"
MAMBA = "mamba"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int                     # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 ⇒ d_model // n_heads

    # --- block pattern --------------------------------------------------
    #: cycled over layers, entries from {"full", "swa", "mamba"}
    block_pattern: Tuple[str, ...] = (ATTN_FULL,)
    window: int = 4096               # SWA window
    causal: bool = True              # False for encoder-only backbones

    # --- gemma2-style extras --------------------------------------------
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    scale_embed: bool = False        # multiply embeddings by sqrt(d_model)
    post_norms: bool = False         # extra post-attn / post-ffn RMSNorms

    # --- MoE --------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.5

    # --- SSM (Mamba2 / SSD) ----------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # --- hybrid (Zamba2) ---------------------------------------------------
    #: apply a shared attention+MLP block after every k backbone layers
    shared_attn_every: int = 0
    n_shared_blocks: int = 2         # zamba2 alternates 2 shared blocks

    # --- modality frontends (stubs) ----------------------------------------
    frontend: str = "none"           # none | audio | vision
    n_vision_tokens: int = 1024      # VLM: patch tokens inside seq_len

    # --- misc ---------------------------------------------------------------
    encoder_only: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    act: str = "silu"                # silu | gelu

    # --- numerics / distribution -------------------------------------------
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"     # master params
    opt_state_dtype: str = "float32" # adam m/v
    remat: bool = True
    #: remat policy: "nothing" rematerializes the whole layer;
    #: "save_attn" saves attention outputs per layer. MEASURED WORSE on the
    #: dry-run (peak +29% at qwen3, traffic −0.2%): the inner flash kv-step
    #: checkpoint already owns the recompute, so the named save only adds
    #: buffers (§Perf iteration 7 — refuted, kept as a switch).
    remat_policy: str = "nothing"
    tp_shards: int = 1               # tensor-parallel degree to pad for

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def gqa(self) -> GQAPadding:
        if self.n_heads == 0:
            return plan_gqa_padding(1, 1, 1)
        return plan_gqa_padding(self.n_heads, self.n_kv_heads,
                                max(self.tp_shards, 1))

    @property
    def vocab_pad(self) -> int:
        return pad_to_multiple(self.vocab_size, max(self.tp_shards, 1) * 8)

    @property
    def d_ff_pad(self) -> int:
        return pad_to_multiple(self.d_ff, max(self.tp_shards, 1)) if self.d_ff else 0

    def layer_kind(self, i: int) -> str:
        return self.block_pattern[i % len(self.block_pattern)]

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.layer_kind(i) for i in range(self.n_layers))

    @property
    def uses_attention(self) -> bool:
        return any(k != MAMBA for k in self.layer_kinds) or self.shared_attn_every > 0

    @property
    def uses_mamba(self) -> bool:
        return any(k == MAMBA for k in self.layer_kinds)

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """True when decode state does not grow linearly with *unbounded*
        full-attention KV (SSM / hybrid / SWA-only archs)."""
        kinds = set(self.layer_kinds)
        if self.shared_attn_every > 0:
            return True  # hybrid: periodic attention, Mamba backbone
        return ATTN_FULL not in kinds

    @property
    def n_params(self) -> int:
        """Logical (unpadded) parameter count, embedding included."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        total = V * D  # embedding
        if not self.tie_embeddings and not self.encoder_only:
            total += D * V
        if self.encoder_only:
            total += D * V  # classifier head
        hd = self.head_dim
        attn = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd \
            + self.n_heads * hd * D
        if self.n_experts:
            ffn = self.n_experts * 3 * D * F + D * self.n_experts  # router
        else:
            ffn = 3 * D * F
        mamba = 0
        if self.uses_mamba:
            din, N = self.d_inner, self.ssm_state
            # in_proj: z, x, B, C, dt  (B/C single group of size N)
            mamba = D * (2 * din + 2 * N + self.ssm_heads) + din * D \
                + self.conv_width * (din + 2 * N) + 3 * self.ssm_heads
        for kind in self.layer_kinds:
            if kind == MAMBA:
                total += mamba
            else:
                total += attn + (ffn if not self.n_experts else 0)
            if self.n_experts and kind != MAMBA:
                total += ffn
        if self.shared_attn_every:
            n_apps = self.n_layers // self.shared_attn_every
            total += self.n_shared_blocks * (attn + 3 * D * self.d_ff)
        total += self.n_layers * 2 * D  # norms (approx)
        return total

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.n_experts:
            return self.n_params
        D, F = self.d_model, self.d_ff
        dense_total = self.n_params - self.n_layers * self.n_experts * 3 * D * F
        return dense_total + self.n_layers * self.top_k * 3 * D * F

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
