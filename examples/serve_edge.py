"""End-to-end serving driver (the paper's kind: inference serving).

    PYTHONPATH=src python examples/serve_edge.py [--users 32]

Places the 10-architecture catalog across edge groups with EGP, routes a
batch of requests with OMS, executes them on real (reduced-config) models
with KV-cache decode, then kills an edge cloud and shows elastic
re-placement — the full production loop on CPU.
"""
import argparse

from repro.launch.serve import run_serving

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=32)
    ap.add_argument("--edges", type=int, default=2)
    args = ap.parse_args()
    run_serving(n_users=args.users, n_edges=args.edges, max_new_tokens=2,
                fail_edge=0)
