"""Quickstart: the PIES problem end-to-end in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

1. Build a synthetic edge topology (paper §VI-B distributions).
2. Solve placement with EGP (Alg. 3) and compare against the exact optimum.
3. Schedule requests with OMS (Alg. 1) and inspect multi-implementation
   routing — the paper's core idea.
"""
import numpy as np

from repro.core import (egp_np, oms_np, opt_np, qos_matrix_np, sigma_np,
                        synthetic_instance)

inst = synthetic_instance(n_users=150, n_edges=5, n_services=30, seed=42)
Q = qos_matrix_np(inst)

x_egp = egp_np(inst, Q)
x_opt = opt_np(inst, Q)
v_egp, v_opt = sigma_np(inst, x_egp, Q), sigma_np(inst, x_opt, Q)
print(f"EGP objective  : {v_egp:8.3f}")
print(f"OPT objective  : {v_opt:8.3f}   (exact per-edge DP)")
print(f"approximation  : {v_egp / v_opt:.4f}   (paper reports ~0.904; "
      f"(1-1/e) guarantee = {1 - 1/np.e:.3f})")

y, _ = oms_np(inst, x_egp, Q)
served = int((y >= 0).sum())
print(f"\nOMS scheduling : {served}/{inst.U} requests served on the edge, "
      f"{inst.U - served} dropped to the central cloud")

# multi-implementation: find a service whose users got different models
for s in range(inst.S):
    users = np.nonzero((inst.u_service == s) & (y >= 0))[0]
    models = {int(y[u]) for u in users}
    if len(models) > 1:
        print(f"\nservice {s}: {len(users)} requests split across "
              f"{len(models)} implementations {sorted(models)}")
        for u in users[:4]:
            print(f"  user {u}: α={inst.u_alpha[u]:.2f} δ={inst.u_delta[u]:.2f}s"
                  f" → model {int(y[u])} (A={inst.sm_acc[y[u]]:.2f})")
        break
