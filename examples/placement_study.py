"""Reproduce the paper's figures quickly (reduced trial counts).

    PYTHONPATH=src python examples/placement_study.py

Fig. 3 (validation vs OPT), Fig. 4 (scaling), Fig. 5 (real-world Table-I
catalog). Full-size runs: python -m benchmarks.run --full.
"""
from benchmarks import fig3_validation, fig4_scale, fig5_realworld

print("== Fig 3: validation vs optimal (reduced) ==")
s3 = fig3_validation.run(trials=2, verbose=False, literal_agp=False)
for k, v in s3.items():
    if not isinstance(v, dict):
        continue  # engine cross-check scalars (e.g. engine_egp_max_abs_diff)
    print(f"  {k:5s} ratio={v['mean_ratio']:.3f} time={v['mean_time_s']*1e3:.1f}ms")
print("  paper: EGP 0.904, AGP 0.900, SCK 0.607")

print("== Fig 4: scaling to 1000 users (reduced) ==")
s4 = fig4_scale.run(trials=1, verbose=False)
print(f"  EGP/SCK objective ratio: {s4['egp_over_sck']:.2f} (paper: ~1.5x)")

print("== Fig 5: real-world Table-I catalog ==")
s5 = fig5_realworld.run(trials=30, verbose=False)
print(f"  EGP placements: {dict((k, v) for k, v in s5['placements']['egp'].items() if v)}")
print("  paper: all non-random algorithms place MobileNet exclusively")
