"""Continuous batching under load: QoS-aware (EDF) vs FCFS admission.

    PYTHONPATH=src python examples/continuous_batching.py

OMS (the paper's Alg. 1) decides *which* implementation serves each
request; the continuous-batching scheduler decides *when* — this example
shows the deadline-aware queueing policy protecting tail QoS as the
arrival rate climbs.
"""
import numpy as np

from repro.serving import Router, default_catalog
from repro.serving.scheduler import simulate

cat = default_catalog()
inst = cat.to_instance(300, 2, storage_capacity=80.0, seed=0)
router = Router("egp")
router.place(inst)
decision = router.route(inst)
comp = np.array([m.comp_cost for m in cat.models])

print(f"{'arrival/s':>10} {'policy':>6} {'meanQoS':>8} {'p10QoS':>8} {'misses':>7}")
for rate in (100, 1000, 4000):
    for policy in ("fcfs", "edf"):
        out = simulate(inst, decision.assignment, comp, policy=policy,
                       arrival_rate=float(rate), max_batch=2, seed=1)
        print(f"{rate:>10} {policy:>6} {out['mean_qos']:8.3f} "
              f"{out['p10_qos']:8.3f} {out['deadline_misses']:7d}")
