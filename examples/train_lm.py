"""Train an LM with the full fault-tolerance stack.

    PYTHONPATH=src python examples/train_lm.py                  # tiny, fast
    PYTHONPATH=src python examples/train_lm.py --preset full \
        --arch smollm_360m --steps 300                          # ~360M run

Demonstrates: seekable pipeline, remat, AdamW, async atomic checkpoints,
crash-resume (kill it mid-run and re-run the same command), optional
gradient compression (--compression topk|int8).
"""
import argparse

from repro.launch.train import run_training

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--compression", default=None)
    args = ap.parse_args()
    out = run_training(arch=args.arch, preset=args.preset, steps=args.steps,
                       checkpoint_dir=args.checkpoint_dir,
                       compression=args.compression)
    print(f"loss: {out['losses'][0]:.4f} → {out['losses'][-1]:.4f} "
          f"over {len(out['losses'])} steps (resumed at {out['start_step']})")
