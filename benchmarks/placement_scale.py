"""Placement-at-scale benchmark: one EGP control tick at U = 10³ … 10⁶.

Compares the three evaluator generations on the same synthetic instance
family (§VI-B catalog: 100 services × ~5.5 implementations, one edge per
~1000 users):

* **dense** — the global-pad batched evaluator (``pad_instances`` +
  ``evaluate_batch``): materializes the ``[U, P]`` QoS matrix and vmaps
  the greedy over per-edge ``[E, U, P]`` masked copies. Memory explodes
  with U, so it only runs up to ``dense_max_u``; beyond that its
  footprint is reported from the same bytes model sweeps use for chunk
  sizing (:func:`repro.sweeps.shard.bytes_per_item`).
* **bucketed** — the same dense evaluator on a mixed-size batch grouped
  into geometric size classes (:func:`repro.workloads.bucket_instances`)
  instead of one global envelope; reported as pad-waste and wall-time vs
  the global pad on a [U, U/2, U/4, U/8] mix.
* **sparse** — top-k candidate pairs + lock-step sparse EGP
  (:func:`repro.workloads.evaluate_sparse`), memory O(U·k + E·P). Exact
  (k = all eligible implementations), validated against the float64 host
  path at ``HOST_PARITY_ATOL`` on paper-scale instances.

Registered as the ``placement_scale`` row of ``benchmarks/run.py`` (mini
U=10³ row in the CI ``--compare`` gate; full grid feeds
``BENCH_trajectory.jsonl``).
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Sequence

import numpy as np


def _label(U: int) -> str:
    return f"u{U // 1000}k" if U >= 1000 else f"u{U}"


def dense_bytes(U: int, P: int, E: int) -> int:
    """Peak dense-evaluator working set (the sweeps chunk-sizing model)."""
    from repro.sweeps.shard import bytes_per_item
    return bytes_per_item((U, P, E + 1))


def sparse_bytes(U: int, P: int, E: int, k: int) -> int:
    """Peak sparse-evaluator working set: candidate pairs (idx i32 + q f32
    + gathered attrs) and the [E, P] greedy state (x, v, considered,
    relevant, scratch)."""
    return 4 * (U * (3 * k + 8) + 6 * E * P + 8 * (U + P + E))


def _tick_sparse(inst, max_iters, k, use_kernel):
    from repro.workloads import evaluate_sparse
    vals, _ = evaluate_sparse([inst], k=k, max_iters=max_iters,
                              use_kernel=use_kernel)
    return float(vals[0])


def _tick_dense(inst, max_iters):
    from repro.workloads import evaluate_batch, pad_instances
    vals, _ = evaluate_batch(pad_instances([inst]), max_iters=max_iters)
    return float(np.asarray(vals)[0])


def run(us: Sequence[int] = (1000,), dense_max_u: int = 20_000,
        host_max_u: int = 2000, bucket_mix: bool = True,
        k: Optional[int] = None, use_kernel: bool = False, seed: int = 0,
        verbose: bool = True) -> Dict:
    """One placement tick per U; returns ``{"per_u": {label: rec}, ...}``.

    Every timed path is run once untimed first (XLA compile / trace), then
    timed — a tick latency, not a compiler benchmark.
    """
    from repro.core.candidates import max_impls_of
    from repro.core.instance import synthetic_instance
    from repro.sweeps.shard import HOST_PARITY_ATOL
    from repro.workloads import evaluate_host

    out: Dict = {"per_u": {}, "host_parity_atol": HOST_PARITY_ATOL}
    rel_diffs = []
    for U in us:
        E = max(10, U // 1000)
        inst = synthetic_instance(n_users=int(U), n_edges=E, seed=seed)
        mi = inst.P + 1  # an edge never picks more than P models
        k_eff = max_impls_of(inst) if k is None else int(k)

        _tick_sparse(inst, mi, k, use_kernel)  # warm
        t0 = time.perf_counter()
        v_sparse = _tick_sparse(inst, mi, k, use_kernel)
        t_sparse = time.perf_counter() - t0

        rec = {
            "U": int(U), "E": E, "P": inst.P, "k": k_eff,
            "sparse_ms": t_sparse * 1e3,
            "sparse_value": v_sparse,
            "dense_bytes": dense_bytes(U, inst.P, E),
            "sparse_bytes": sparse_bytes(U, inst.P, E, k_eff),
        }
        rec["mem_ratio"] = rec["dense_bytes"] / rec["sparse_bytes"]

        if U <= dense_max_u:
            _tick_dense(inst, mi)  # warm
            t0 = time.perf_counter()
            v_dense = _tick_dense(inst, mi)
            t_dense = time.perf_counter() - t0
            rec["dense_ms"] = t_dense * 1e3
            rec["speedup"] = t_dense / t_sparse
            rec["dense_sparse_rel_diff"] = (abs(v_dense - v_sparse)
                                            / max(1.0, abs(v_dense)))
        if U <= host_max_u:
            v_host = float(evaluate_host([inst])[0])
            rel = abs(v_sparse - v_host) / max(1.0, abs(v_host))
            rec["host_rel_diff"] = rel
            rel_diffs.append(rel)

        out["per_u"][_label(int(U))] = rec
        if verbose:
            d = rec.get("dense_ms")
            print(f"[placement_scale] U={U:>7d} sparse {rec['sparse_ms']:9.2f} ms"
                  + (f"  dense {d:9.2f} ms  ({rec['speedup']:.1f}x)"
                     if d is not None else "  dense (bytes model only)")
                  + f"  mem x{rec['mem_ratio']:.0f}", flush=True)

    out["rel_diff_paper"] = max(rel_diffs) if rel_diffs else None

    # the mixed-size batch runs through the *dense* evaluator, so it is
    # subject to the same memory wall as the dense column — skip it when
    # even the smallest requested U is past dense_max_u (e.g. a measured
    # 10^6 sparse-only run)
    if bucket_mix and min(us) <= dense_max_u:
        from repro.workloads import (bucket_instances, evaluate_batch,
                                     pad_instances)
        U0 = int(min(us))
        mix = [synthetic_instance(n_users=max(8, U0 // (2 ** i)),
                                  n_edges=max(4, (U0 // (2 ** i)) // 1000),
                                  seed=seed + i) for i in range(4)]
        mi = max(i.P for i in mix) + 1

        def tick_global():
            v, _ = evaluate_batch(pad_instances(mix), max_iters=mi)
            return np.asarray(v, np.float64)

        def tick_bucketed():
            v, _ = evaluate_batch(bucket_instances(mix), max_iters=mi)
            return np.asarray(v, np.float64)

        vg = tick_global()
        t0 = time.perf_counter()
        vg = tick_global()
        t_global = time.perf_counter() - t0
        vb = tick_bucketed()
        t0 = time.perf_counter()
        vb = tick_bucketed()
        t_bucket = time.perf_counter() - t0
        bb = bucket_instances(mix)
        out["bucket_mix"] = {
            "global_ms": t_global * 1e3,
            "bucket_ms": t_bucket * 1e3,
            "pad_waste": bb.pad_waste,
            "n_buckets": len(bb.buckets),
            "max_abs_diff": float(np.abs(vg - vb).max()),
        }
        if verbose:
            bm = out["bucket_mix"]
            print(f"[placement_scale] mixed batch: global "
                  f"{bm['global_ms']:.2f} ms vs bucketed "
                  f"{bm['bucket_ms']:.2f} ms, pad_waste={bm['pad_waste']:.2f},"
                  f" max|Δ|={bm['max_abs_diff']:.1e}", flush=True)
    return out


if __name__ == "__main__":
    run(us=(1000, 10_000), verbose=True)
