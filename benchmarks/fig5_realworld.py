"""Fig. 5 / Table I reproduction — the real-world single-slot case.

Six ImageNet classifier implementations (Table I accuracies + measured
delays), one edge cloud, R_e = 1 placement slot, 300 requests with the
§VI-C threshold distributions. Paper result: every non-random algorithm
exclusively places MobileNet (Fig. 5b); non-random QoS concentrates near
the top (Fig. 5a).
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.core import (REALWORLD_CATALOG, agp_np, egp_np, opt_np, oms_np,
                        qos_matrix_np, realworld_instance, rnd_np, sck_np,
                        schedule_value_np)

OUT = Path(__file__).resolve().parent.parent / "experiments" / "paper"


def run(trials: int = 100, verbose: bool = True):
    names = [n for n, _, _ in REALWORLD_CATALOG]
    placements = {a: {n: 0 for n in names}
                  for a in ("opt", "agp", "egp", "sck", "rnd")}
    qos = {a: [] for a in placements}
    for t in range(trials):
        inst = realworld_instance(seed=t)
        Q = qos_matrix_np(inst)
        for algo, fn in [("opt", opt_np), ("agp", agp_np), ("egp", egp_np),
                         ("sck", sck_np)]:
            x = fn(inst, Q)
            chosen = np.nonzero(x[0])[0]
            for c in chosen:
                placements[algo][names[c]] += 1
            _, val = oms_np(inst, x, Q)
            qos[algo].append(val / inst.U)
        x, y = rnd_np(inst, seed=t)
        for c in np.nonzero(x[0])[0]:
            placements["rnd"][names[c]] += 1
        qos["rnd"].append(schedule_value_np(inst, y, Q) / inst.U)

    summary = {
        "placements": placements,
        "mean_qos": {a: float(np.mean(v)) for a, v in qos.items()},
        "p10_qos": {a: float(np.percentile(v, 10)) for a, v in qos.items()},
    }
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig5_realworld.json").write_text(json.dumps(summary, indent=1))
    if verbose:
        print(json.dumps(summary, indent=1))
    return summary


if __name__ == "__main__":
    run()
