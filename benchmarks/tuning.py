"""Auto-tuner benchmark — sweep store → fitted table → Pareto frontier.

Runs a small ``kind="serving"`` sweep into a throwaway store, fits the
per-scenario ``(switching_cost, stickiness)`` lookup table from it
(:mod:`repro.tuning.fit`), extracts the (QoS, miss-rate) /
(accuracy, latency) Pareto frontiers (:mod:`repro.tuning.pareto`), and
reports the fitted knobs plus frontier sizes — the ``tuning_fit`` row of
``benchmarks/run.py``.

    PYTHONPATH=src python -m benchmarks.tuning
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path
from typing import Dict, Sequence

from repro.sweeps import SweepSpec, run_sweep
from repro.tuning import fit_table, frontier_points

#: Congested-but-fast load point (see tests/test_horizon.py::LOAD).
SMALL = {"n_user_slots": 32, "n_services": 8, "max_impls": 3, "n_edges": 4,
         "prompt_tokens": 768, "new_tokens": 64, "max_batch": 4}
KNOB_GRID = ((0.0, 0.0), (0.0, 3.0), (2.0, 0.0), (2.0, 3.0))


def run(scenarios: Sequence[str] = ("steady", "flash_crowd"),
        seeds: Sequence[int] = (0, 1), n_ticks: int = 3,
        verbose: bool = True) -> Dict:
    grid = tuple(
        tuple(sorted({**SMALL, "switching_cost": sc,
                      "stickiness": st}.items()))
        for sc, st in KNOB_GRID)
    spec = SweepSpec(kind="serving", scenarios=tuple(scenarios),
                     seeds=tuple(seeds), n_ticks=n_ticks,
                     algos=("edf", "fcfs"), override_grid=grid)
    out: Dict = {"n_items": len(spec.expand())}
    with tempfile.TemporaryDirectory(prefix="tuning_bench_") as tmp:
        store = Path(tmp) / "store"
        t0 = time.perf_counter()
        run_sweep(spec, store_dir=store)
        out["sweep_s"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        table = fit_table(store)
        out["fit_s"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        frontiers = frontier_points(store)
        out["pareto_s"] = time.perf_counter() - t0
    out["table"] = table["scenarios"]
    out["frontier_sizes"] = {
        s: sum(p.qos_frontier for p in pts) for s, pts in frontiers.items()}
    if verbose:
        for name, row in sorted(out["table"].items()):
            print(f"[tuning] {name:<14} -> switching_cost="
                  f"{row['switching_cost']:g} stickiness="
                  f"{row['stickiness']:g} (qos {row['mean_qos']:.4f} "
                  f"±{row['ci95']:.4f}); "
                  f"{out['frontier_sizes'][name]} frontier point(s)")
    return out


if __name__ == "__main__":
    run()
