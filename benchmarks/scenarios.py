"""Scenario sweep benchmark — the repro.sweeps engine end-to-end.

Runs every registered scenario (steady, diurnal, flash_crowd,
mobility_churn, edge_failure, trace_replay) over a (seed × tick) grid
through :func:`repro.sweeps.run_sweep` — the same declarative
chunked/sharded path that drives `python -m repro.sweeps` (plain jitted
``vmap`` on one device, ``shard_map`` across the mesh batch axis on many)
— and validates the engine's objectives against the per-instance host path
(``egp_np`` + ``sigma_np``, atol 1e-4). Also reports the dynamic-policy
comparison (static / greedy / hysteresis) on the churn-heavy scenarios.

    PYTHONPATH=src python -m benchmarks.scenarios
"""
from __future__ import annotations

import time
from typing import Dict, Sequence

import numpy as np

from repro.core.dynamic import evaluate_horizon
from repro.sweeps import HOST_PARITY_ATOL, SweepSpec, materialize, run_sweep
from repro.workloads import evaluate_host, list_scenarios

#: acceptance tolerance between batched float32 and host float64 objectives
ATOL = HOST_PARITY_ATOL


def run(seeds: Sequence[int] = (0, 1), n_ticks: int = 4, algo: str = "egp",
        switching_cost: float = 3.0, verbose: bool = True) -> Dict:
    names = list_scenarios()
    spec = SweepSpec(scenarios=tuple(names), seeds=tuple(seeds),
                     n_ticks=n_ticks, algos=(algo,))

    t0 = time.perf_counter()
    result = run_sweep(spec)  # in-memory: chunked accelerator evaluation
    batched_s = time.perf_counter() - t0

    instances = []
    for name in names:
        instances += materialize(name, (), [(s, t) for s in seeds
                                            for t in range(n_ticks)])
    n = len(instances)
    assert n >= 16, f"sweep too small for a meaningful batch ({n} < 16)"

    t0 = time.perf_counter()
    host = evaluate_host(instances, algo=algo)
    host_s = time.perf_counter() - t0

    flat = np.concatenate([result.values[(name, algo)].reshape(-1)
                           for name in names])
    max_abs_diff = float(np.abs(flat - host).max())
    assert max_abs_diff <= ATOL, \
        f"batched/host divergence {max_abs_diff:.2e} > {ATOL}"

    per_scenario = {
        name: {
            "mean_sigma": float(result.values[(name, algo)].mean()),
            "min_sigma": float(result.values[(name, algo)].min()),
            "max_sigma": float(result.values[(name, algo)].max()),
        }
        for name in names
    }

    dynamic = {}
    for name in ("flash_crowd", "mobility_churn"):
        dynamic[name] = evaluate_horizon(
            name, switching_cost=switching_cost, seed=int(seeds[0]),
            n_ticks=max(n_ticks, 6))

    summary = {
        "n_instances": n,
        "n_scenarios": len(names),
        "algo": algo,
        "max_abs_diff": max_abs_diff,
        "batched_s": batched_s,
        "host_s": host_s,
        "engine": result.execution,
        "per_scenario": per_scenario,
        "dynamic": dynamic,
    }
    if verbose:
        ex = result.execution
        print(f"{n} instances across {len(names)} scenarios, algo={algo}")
        print(f"engine ({ex['chunks_computed']} chunk(s) via {ex['path']}, "
              f"{ex['n_devices']} device(s), incl. compile): {batched_s:.3f}s; "
              f"host loop: {host_s:.3f}s; max|Δσ| = {max_abs_diff:.2e}")
        for name in names:
            s = per_scenario[name]
            print(f"  {name:16s} σ mean {s['mean_sigma']:7.2f} "
                  f"[{s['min_sigma']:.2f}, {s['max_sigma']:.2f}]")
        for name, pol in dynamic.items():
            print(f"  dynamic {name}: " + ", ".join(
                f"{k}={v:.1f}" for k, v in pol.items()))
    return summary


if __name__ == "__main__":
    run()
