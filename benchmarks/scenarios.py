"""Scenario sweep benchmark — the repro.workloads subsystem end-to-end.

Runs every registered scenario (steady, diurnal, flash_crowd,
mobility_churn, edge_failure) over a (seed × tick) grid, evaluates the full
instance stack in **one** jitted vmapped accelerator call, and validates the
batched objectives against the per-instance host path (``egp_np`` +
``sigma_np``, atol 1e-4). Also reports the dynamic-policy comparison
(static / greedy / hysteresis) on the churn-heavy scenarios.

    PYTHONPATH=src python -m benchmarks.scenarios
"""
from __future__ import annotations

import time
from typing import Dict, Sequence

import numpy as np

from repro.core.dynamic import evaluate_horizon
from repro.workloads import evaluate_host, list_scenarios, sweep

#: acceptance tolerance between batched float32 and host float64 objectives
ATOL = 1e-4


def run(seeds: Sequence[int] = (0, 1), n_ticks: int = 4, algo: str = "egp",
        switching_cost: float = 3.0, verbose: bool = True) -> Dict:
    names = list_scenarios()

    t0 = time.perf_counter()
    result = sweep(names, seeds=seeds, n_ticks=n_ticks, algo=algo)
    batched_s = time.perf_counter() - t0
    instances = result["instances"]
    n = len(instances)
    assert n >= 16, f"sweep too small for a meaningful batch ({n} < 16)"

    t0 = time.perf_counter()
    host = evaluate_host(instances, algo=algo)
    host_s = time.perf_counter() - t0

    flat = np.concatenate([result["values"][name].reshape(-1)
                           for name in names])
    max_abs_diff = float(np.abs(flat - host).max())
    assert max_abs_diff <= ATOL, \
        f"batched/host divergence {max_abs_diff:.2e} > {ATOL}"

    per_scenario = {
        name: {
            "mean_sigma": float(result["values"][name].mean()),
            "min_sigma": float(result["values"][name].min()),
            "max_sigma": float(result["values"][name].max()),
        }
        for name in names
    }

    dynamic = {}
    for name in ("flash_crowd", "mobility_churn"):
        dynamic[name] = evaluate_horizon(
            name, switching_cost=switching_cost, seed=int(seeds[0]),
            n_ticks=max(n_ticks, 6))

    summary = {
        "n_instances": n,
        "n_scenarios": len(names),
        "algo": algo,
        "max_abs_diff": max_abs_diff,
        "batched_s": batched_s,
        "host_s": host_s,
        "per_scenario": per_scenario,
        "dynamic": dynamic,
    }
    if verbose:
        print(f"{n} instances across {len(names)} scenarios, algo={algo}")
        print(f"batched (1 jitted call incl. compile): {batched_s:.3f}s; "
              f"host loop: {host_s:.3f}s; max|Δσ| = {max_abs_diff:.2e}")
        for name in names:
            s = per_scenario[name]
            print(f"  {name:16s} σ mean {s['mean_sigma']:7.2f} "
                  f"[{s['min_sigma']:.2f}, {s['max_sigma']:.2f}]")
        for name, pol in dynamic.items():
            print(f"  dynamic {name}: " + ", ".join(
                f"{k}={v:.1f}" for k, v in pol.items()))
    return summary


if __name__ == "__main__":
    run()
