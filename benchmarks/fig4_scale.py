"""Fig. 4 reproduction — scaling in the number of requests.

Paper setup: U ∈ {100, 200, …, 1000}; EGP vs SCK vs RND (OPT omitted at
scale, as in the paper — its CBC runs took up to 20 h; our exact DP is
still run optionally for ground truth since it stays fast). Headline:
EGP ≈ 1.5× SCK objective while remaining the fastest.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import (egp_np, agp_np, opt_np, qos_matrix_np, rnd_np,
                        sck_np, schedule_value_np, sigma_np,
                        synthetic_instance)

OUT = Path(__file__).resolve().parent.parent / "experiments" / "paper"


def run(trials: int = 10, users=tuple(range(100, 1001, 100)), seed0: int = 0,
        with_opt: bool = True, verbose: bool = True):
    rows = []
    for U in users:
        for t in range(trials):
            inst = synthetic_instance(U, seed=seed0 + 7919 * t + U)
            Q = qos_matrix_np(inst)
            vals, times = {}, {}
            for name, fn in [("egp", egp_np), ("agp", agp_np),
                             ("sck", sck_np)] + ([("opt", opt_np)]
                                                 if with_opt else []):
                t0 = time.perf_counter()
                x = fn(inst, Q)
                times[name] = time.perf_counter() - t0
                vals[name] = sigma_np(inst, x, Q)
            t0 = time.perf_counter()
            _, y = rnd_np(inst, seed=t)
            times["rnd"] = time.perf_counter() - t0
            vals["rnd"] = schedule_value_np(inst, y, Q)
            rows.append({"U": U, "trial": t, "values": vals, "times": times})
        if verbose:
            sub = [r for r in rows if r["U"] == U]
            means = {k: float(np.mean([r["values"][k] for r in sub]))
                     for k in sub[0]["values"]}
            print(f"U={U}: mean values {({k: round(v,1) for k,v in means.items()})}")

    summary = {}
    names = rows[0]["values"].keys()
    for name in names:
        summary[name] = {
            "mean_value": float(np.mean([r["values"][name] for r in rows])),
            "mean_time_s": float(np.mean([r["times"][name] for r in rows])),
        }
    if "opt" in summary:
        for name in names:
            summary[name]["mean_ratio"] = float(np.mean(
                [r["values"][name] / max(r["values"]["opt"], 1e-9)
                 for r in rows]))
    egp_vs_sck = float(np.mean([r["values"]["egp"] / max(r["values"]["sck"], 1e-9)
                                for r in rows]))
    summary["egp_over_sck"] = egp_vs_sck
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig4_scale.json").write_text(
        json.dumps({"rows": rows, "summary": summary}, indent=1))
    if verbose:
        print(json.dumps(summary, indent=1))
    return summary


if __name__ == "__main__":
    run()
