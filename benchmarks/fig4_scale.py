"""Fig. 4 reproduction — scaling in the number of requests.

Paper setup: U ∈ {100, 200, …, 1000}; EGP vs SCK vs RND (OPT omitted at
scale, as in the paper — its CBC runs took up to 20 h; our exact DP is
still run optionally for ground truth since it stays fast). Headline:
EGP ≈ 1.5× SCK objective while remaining the fastest.

Since PR 2 the grid runs through the :mod:`repro.sweeps` engine: EGP/AGP
on the batched accelerator path (auto-chunked to the memory budget,
``shard_map``-sharded when more than one device exists — the scaling
story), SCK/RND/OPT via the host executor. The smallest-U group is
additionally recomputed on the host path and compared at 1e-4, so the
classic validation survives the rewiring.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import numpy as np

from repro.sweeps import HOST_PARITY_ATOL, SweepSpec, run_sweep

OUT = Path(__file__).resolve().parent.parent / "experiments" / "paper"

#: tolerance between the engine's float32 batched EGP and host float64
ENGINE_ATOL = HOST_PARITY_ATOL


def run(trials: int = 10, users=tuple(range(100, 1001, 100)), seed0: int = 0,
        with_opt: bool = True, host_check: bool = True,
        verbose: bool = True):
    accel_algos = ["egp", "agp"]
    host_algos = ["sck", "rnd"] + (["opt"] if with_opt else [])
    algo_names = accel_algos + host_algos

    rows = []
    host_check_diff = None
    for U in users:
        # the classic instance stream: synthetic_instance(U, seed0+7919t+U)
        seeds = tuple(seed0 + 7919 * t + U for t in range(trials))
        spec = SweepSpec(scenarios=("synthetic",), seeds=seeds, n_ticks=1,
                         algos=tuple(algo_names),
                         override_grid=({"n_users": U},))
        res = run_sweep(spec)
        (variant,) = {v for v, _ in res.values}

        if host_check and U == min(users):
            host = run_sweep(dataclasses.replace(
                spec, algos=("egp",), force_host=("egp",)))
            host_check_diff = float(np.abs(
                res.values[(variant, "egp")]
                - host.values[(variant, "egp")]).max())
            assert host_check_diff <= ENGINE_ATOL, \
                f"engine EGP diverges from host at U={U}: " \
                f"{host_check_diff:.2e} > {ENGINE_ATOL}"

        for t in range(trials):
            vals = {a: float(res.values[(variant, a)][t, 0])
                    for a in algo_names}
            times = {a: float(res.times[(variant, a)][t, 0])
                     for a in algo_names}
            rows.append({"U": U, "trial": t, "values": vals, "times": times})
        if verbose:
            sub = [r for r in rows if r["U"] == U]
            means = {k: float(np.mean([r["values"][k] for r in sub]))
                     for k in sub[0]["values"]}
            print(f"U={U}: mean values {({k: round(v,1) for k,v in means.items()})}")

    summary = {}
    names = rows[0]["values"].keys()
    for name in names:
        summary[name] = {
            "mean_value": float(np.mean([r["values"][name] for r in rows])),
            "mean_time_s": float(np.mean([r["times"][name] for r in rows])),
        }
    if "opt" in summary:
        for name in names:
            summary[name]["mean_ratio"] = float(np.mean(
                [r["values"][name] / max(r["values"]["opt"], 1e-9)
                 for r in rows]))
    egp_vs_sck = float(np.mean([r["values"]["egp"] / max(r["values"]["sck"], 1e-9)
                                for r in rows]))
    summary["egp_over_sck"] = egp_vs_sck
    if host_check_diff is not None:
        summary["engine_egp_max_abs_diff"] = host_check_diff
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig4_scale.json").write_text(
        json.dumps({"rows": rows, "summary": summary}, indent=1))
    if verbose:
        print(json.dumps(summary, indent=1))
    return summary


if __name__ == "__main__":
    run()
