"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. For every (arch × shape × mesh) cell we derive, from
the trip-count-corrected per-device HLO cost model (repro.analysis.hlo_cost):

  compute   = HLO_FLOPs/dev ÷ 197e12        [s]
  memory    = HLO_bytes/dev  ÷ 819e9        [s]
  collective= coll_bytes/dev ÷ 50e9         [s]   (operand-bytes convention)

plus MODEL_FLOPS (6·N·tokens train / 2·N_active·tokens inference), the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs, the dominant term, and the
roofline fraction = ideal-model-compute-time ÷ max(term) — the headline
§Perf score. Raw XLA cost_analysis is recorded for reference but NOT used
(XLA counts while bodies once; see hlo_cost docstring).
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12      # bf16 / chip (MXU)
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link
VPU_FLOPS = 2.5e12       # f32 elementwise / chip (order-of-magnitude VPU peak)

ARTIFACTS = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"
OUT = Path(__file__).resolve().parent.parent / "experiments"


def model_flops(rec: dict) -> float:
    """Useful model FLOPs for the whole step (all devices)."""
    n = rec["n_params"]
    n_act = rec["n_active_params"]
    shape = rec["shape"]
    kind = rec["kind"]
    tokens = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
              "decode_32k": 128, "long_500k": 1}[shape]
    if kind == "train":
        return 6.0 * n_act * tokens
    return 2.0 * n_act * tokens


def analyze_record(rec: dict) -> dict:
    c = rec["corrected"]
    devices = rec["devices"]
    compute = c["flops"] / PEAK_FLOPS
    memory = c["hbm_bytes"] / HBM_BW
    coll = c["collectives"]["total_operand_bytes"] / ICI_BW
    # supplementary: ring-wire bytes (all-reduce physically moves ~2× its
    # operand = reduce-scatter + all-gather); the spec's collective term
    # uses plain operand bytes — both are reported.
    wire = sum(v["operand_bytes"] * (2.0 if k == "all-reduce" else 1.0)
               for k, v in c["collectives"].items()
               if isinstance(v, dict)) / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_total = c["flops"] * devices
    ideal = mf / devices / PEAK_FLOPS
    bound = max(terms.values())
    mem = rec.get("memory", {}).get("per_device_hbm_bytes")
    colls = {k: v for k, v in c["collectives"].items()
             if isinstance(v, dict) and v.get("operand_bytes", 0) > 0}
    biggest_coll = max(colls, key=lambda k: colls[k]["operand_bytes"]) \
        if colls else "-"
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"], "devices": devices,
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "collective_wire_s": wire,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "roofline_fraction": ideal / bound if bound else 0.0,
        "hbm_fit": (mem or 0) <= 16 * 2**30 if mem else None,
        "mem_gib": (mem or 0) / 2**30,
        "biggest_collective": biggest_coll,
    }


def what_would_help(row: dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return (f"dominant {row['biggest_collective']}: replace partial-sum "
                "all-reduce with reduce-scatter (SP shard_map projections) "
                "/ overlap FSDP gathers with compute")
    if d == "memory":
        if row["kind"] == "decode":
            return ("decode streams the KV cache: shrink cache bytes "
                    "(true-KV heads + seq-sharded decode, ring buffers)")
        return ("raise arithmetic intensity: larger attention chunks, "
                "fewer remat boundaries, bf16 residuals")
    return "compute-bound: MXU-align tiles; reduce remat recompute"


def placement_rows(us=(100_000, 1_000_000), k: int = 10) -> list:
    """Analytic roofline for the segmented placement kernels (no dry-run
    artifact — these are elementwise VPU kernels, so the model is a flat
    bytes/flops count, not an HLO walk).

    Per tick: ``qos_candidates`` touches every (user, candidate) pair once
    (4 f32 candidate attrs in, 1 f32 QoS out, ~12 flops of Eq. 1–6
    arithmetic); ``greedy_argmax`` re-reads the ``[E, P]`` benefit + mask
    state every pick, for up to ~k picks per edge. Intensity is < 1
    flop/byte on both — firmly memory-bound, so tick latency at scale is
    HBM traffic ÷ bandwidth, which is what the U = 10⁵…10⁶ targets in
    ROADMAP are sized against.
    """
    rows = []
    for U in us:
        E, P = max(10, U // 1000), 550
        cand_bytes = 16 * U + (4 * 4 + 4) * U * k
        cand_flops = 12 * U * k
        picks = k  # an edge stops after ~k picks (one per local service)
        greedy_bytes = picks * 8 * E * P
        greedy_flops = picks * 3 * E * P
        bytes_total = cand_bytes + greedy_bytes
        flops_total = cand_flops + greedy_flops
        mem_s = bytes_total / HBM_BW
        comp_s = flops_total / VPU_FLOPS
        rows.append({
            "arch": "placement_sparse", "shape": f"u{U // 1000}k",
            "mesh": "vpu-analytic", "kind": "analytic",
            "bytes": bytes_total, "flops": flops_total,
            "intensity_flop_per_byte": flops_total / bytes_total,
            "compute_s": comp_s, "memory_s": mem_s,
            "dominant": "memory" if mem_s >= comp_s else "compute",
            "tick_bound_ms": max(mem_s, comp_s) * 1e3,
        })
    return rows


def build(mesh_filter: str = None, verbose: bool = True):
    rows = []
    for f in sorted(ARTIFACTS.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            if rec.get("status") == "skip":
                rows.append({"arch": rec["arch"], "shape": rec["shape"],
                             "mesh": rec["mesh"], "skip": rec["skip_reason"]})
            continue
        if mesh_filter and rec["mesh"] != mesh_filter:
            continue
        rows.append(analyze_record(rec))

    table = [r for r in rows if "skip" not in r]
    table.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    lines = ["| arch | shape | mesh | compute s | memory s | coll s | "
             "dominant | MF/HLO | roofline | mem GiB | fits |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in table:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {r['mem_gib']:.1f} | {'Y' if r['hbm_fit'] else 'N'} |")
    prows = placement_rows()
    lines += ["", "### Placement kernels (analytic, VPU)", "",
              "| arch | shape | intensity F/B | memory s | compute s | "
              "dominant | tick bound ms |",
              "|---|---|---|---|---|---|---|"]
    for r in prows:
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['intensity_flop_per_byte']:.2f} | {r['memory_s']:.3e} "
            f"| {r['compute_s']:.3e} | {r['dominant']} "
            f"| {r['tick_bound_ms']:.3f} |")
    rows += prows
    md = "\n".join(lines)
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "roofline.json").write_text(json.dumps(rows, indent=1))
    (OUT / "roofline_table.md").write_text(md)
    if verbose:
        print(md)
    return rows


if __name__ == "__main__":
    build()
