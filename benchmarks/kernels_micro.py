"""Kernel microbenchmarks (host wall-time; interpret-mode kernels on CPU
validate correctness — TPU timing comes from the roofline model, since the
container has no TPU). Emits name,us_per_call,derived CSV rows."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(verbose: bool = True):
    rows = []
    # --- qos matrix: jnp ref vs numpy core (control-plane throughput) -----
    from repro.core import synthetic_instance, qos_matrix_np, qos_matrix_jnp
    from repro.kernels.qos_matrix.ref import qos_matrix_ref
    inst = synthetic_instance(2000, seed=0)
    ji = inst.as_jax()
    t_np = _time(lambda: qos_matrix_np(inst))
    f_jnp = jax.jit(qos_matrix_jnp)
    t_jnp = _time(f_jnp, ji)
    UP = inst.U * inst.P
    rows.append(("qos_matrix_numpy", t_np, f"{UP/t_np:.0f} pairs/us"))
    rows.append(("qos_matrix_jnp_jit", t_jnp, f"{UP/t_jnp:.0f} pairs/us"))

    # --- qos matrix Pallas dispatcher, timed via repro.obs span durations ---
    # block_until_ready inside the span: JAX dispatch is async, so the
    # ops-level kernel.qos_matrix span alone covers dispatch, not compute
    from repro import obs
    from repro.obs import trace as _obs_trace
    from repro.kernels.qos_matrix.ops import qos_matrix_from_instance
    small = synthetic_instance(256, seed=0)
    sji = small.as_jax()
    prev = obs.get_tracer()
    tr = obs.enable(capacity=256)
    try:
        for _ in range(2):  # warmup (first call pays the XLA compile)
            jax.block_until_ready(qos_matrix_from_instance(sji))
        for _ in range(5):
            with obs.span("bench.qos_matrix_pallas"):
                jax.block_until_ready(qos_matrix_from_instance(sji))
        durs = tr.span_durations_s("bench.qos_matrix_pallas")
        t_k = float(np.mean(durs)) * 1e6
        up_small = small.U * small.P
        rows.append(("qos_matrix_pallas", t_k,
                     f"{up_small/t_k:.0f} pairs/us obs-span "
                     f"(interpret off-TPU)"))
    finally:
        _obs_trace._TRACER = prev  # restore whatever tracer the caller had

    # --- segmented candidate kernels (sparse placement path) ----------------
    from repro.core.candidates import impl_table_np, max_impls_of
    from repro.kernels.qos_matrix.ops import (greedy_argmax,
                                              qos_candidates_from_instance)
    table = jnp.asarray(impl_table_np(np.asarray(small.sm_service), small.S))
    kM = max_impls_of(small)
    for use_kernel, tag in ((False, "jnp_ref"), (True, "pallas_interp")):
        f = lambda: qos_candidates_from_instance(sji, table,
                                                 use_kernel=use_kernel)
        t = _time(lambda: f()[1])
        rows.append((f"qos_candidates_{tag}", t,
                     f"{small.U * kM / t:.0f} pairs/us U={small.U} k={kM}"))
    E, P = 64, small.P
    rng_g = np.random.default_rng(1)
    v = jnp.asarray(rng_g.normal(size=(E, P)), jnp.float32)
    m = jnp.asarray(rng_g.random((E, P)) < 0.5)
    for use_kernel, tag in ((False, "jnp_ref"), (True, "pallas_interp")):
        t = _time(lambda: greedy_argmax(v, m, use_kernel=use_kernel)[1])
        rows.append((f"greedy_argmax_{tag}", t, f"rows/us {E/t:.2f} E={E}"))

    # --- placement algorithms (paper control plane) -------------------------
    from repro.core import egp_np, agp_np, opt_np, qos_matrix_np as qmn
    Q = qmn(inst)
    rows.append(("egp_place_u2000", _time(lambda: egp_np(inst, Q), iters=3),
                 "host"))
    rows.append(("agp_place_u2000", _time(lambda: agp_np(inst, Q), iters=3),
                 "host"))

    # --- flash attention ref (jnp path used by the dry-run) -----------------
    from repro.kernels.flash_attention.ref import attention_ref
    rng = np.random.default_rng(0)
    B, S, Hq, Hkv, hd = 1, 512, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, Hq, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)), jnp.bfloat16)
    fa = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    t = _time(fa, q, k, v, iters=3)
    fl = 4 * B * Hq * S * S * hd / 2
    rows.append(("attention_ref_512", t, f"{fl/t/1e6:.2f} GFLOP/s host"))

    # --- ssd ref -------------------------------------------------------------
    from repro.models.layers import ssd_chunked
    B, L, H, P, N = 1, 1024, 8, 64, 64
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dtA = -jnp.asarray(rng.uniform(0.01, 0.4, (B, L, H)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    f = jax.jit(lambda *a: ssd_chunked(*a, chunk=128))
    t = _time(f, x, dtA, bm, cm, iters=3)
    rows.append(("ssd_chunked_1024", t, "host"))

    if verbose:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run()
