"""Fig. 3 reproduction — validation against the optimal solution.

Paper setup (§VI-B): |E|=10, |S|=100, impls ~ U{1..10}, U ∈ {50..250},
10 trials. Fig. 3a: objective value per algorithm (OPT, AGP, EGP, SCK,
RND). Fig. 3b: runtime. Paper's headline: AGP ≈ 0.900·OPT, EGP ≈ 0.904·OPT
on average; EGP fastest.

Our OPT is the exact per-edge subset/knapsack DP (see core/opt.py) — same
optima as the paper's CBC solves, minus the 20-hour runtimes. ``agp`` here
is the closed-form-marginal implementation (identical picks); the literal
σ-recomputation variant is timed separately as ``agp_literal`` to show the
runtime separation the paper reports.

Since PR 2 the per-(U, trial, algorithm) grid runs through the
:mod:`repro.sweeps` engine — the classic host-path algorithms via its host
executor (exact float64 semantics, per-instance timings preserved) and,
when ``validate_engine`` is set, EGP additionally through the batched
accelerator path, checked against the host values at 1e-4.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import numpy as np

from repro.sweeps import HOST_PARITY_ATOL, SweepSpec, run_sweep

OUT = Path(__file__).resolve().parent.parent / "experiments" / "paper"

#: tolerance between the engine's float32 batched EGP and host float64
ENGINE_ATOL = HOST_PARITY_ATOL


def run(trials: int = 10, users=(50, 100, 150, 200, 250), seed0: int = 0,
        literal_agp: bool = True, validate_engine: bool = True,
        verbose: bool = True):
    algo_names = ["opt", "agp", "egp", "sck", "rnd"]
    if literal_agp:
        algo_names.append("agp_literal")

    rows, engine_diffs = [], []
    for U in users:
        # the classic instance stream: synthetic_instance(U, seed0+1000t+U)
        seeds = tuple(seed0 + 1000 * t + U for t in range(trials))
        spec = SweepSpec(scenarios=("synthetic",), seeds=seeds, n_ticks=1,
                         algos=tuple(algo_names),
                         override_grid=({"n_users": U},),
                         force_host=("egp", "agp"))
        res = run_sweep(spec)
        (variant,) = {v for v, _ in res.values}

        if validate_engine:
            accel = run_sweep(dataclasses.replace(
                spec, algos=("egp",), force_host=()))
            diff = np.abs(accel.values[(variant, "egp")]
                          - res.values[(variant, "egp")])
            engine_diffs.append(float(diff.max()))
            assert engine_diffs[-1] <= ENGINE_ATOL, \
                f"engine EGP diverges from host at U={U}: " \
                f"{engine_diffs[-1]:.2e} > {ENGINE_ATOL}"

        for t in range(trials):
            vals = {a: float(res.values[(variant, a)][t, 0])
                    for a in algo_names}
            times = {a: float(res.times[(variant, a)][t, 0])
                     for a in algo_names}
            rows.append({"U": U, "trial": t, "values": vals, "times": times})
            if verbose:
                r = {k: round(v / max(vals["opt"], 1e-9), 3)
                     for k, v in vals.items()}
                print(f"U={U} trial={t}: ratios {r}")

    summary = {}
    for name in algo_names:
        ratios = [r["values"][name] / max(r["values"]["opt"], 1e-9)
                  for r in rows]
        ts = [r["times"][name] for r in rows]
        summary[name] = {"mean_ratio": float(np.mean(ratios)),
                         "min_ratio": float(np.min(ratios)),
                         "mean_time_s": float(np.mean(ts))}
    if engine_diffs:
        summary["engine_egp_max_abs_diff"] = float(max(engine_diffs))
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig3_validation.json").write_text(
        json.dumps({"rows": rows, "summary": summary}, indent=1))
    if verbose:
        print(json.dumps(summary, indent=1))
    return summary


if __name__ == "__main__":
    run()
