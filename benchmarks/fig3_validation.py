"""Fig. 3 reproduction — validation against the optimal solution.

Paper setup (§VI-B): |E|=10, |S|=100, impls ~ U{1..10}, U ∈ {50..250},
10 trials. Fig. 3a: objective value per algorithm (OPT, AGP, EGP, SCK,
RND). Fig. 3b: runtime. Paper's headline: AGP ≈ 0.900·OPT, EGP ≈ 0.904·OPT
on average; EGP fastest.

Our OPT is the exact per-edge subset/knapsack DP (see core/opt.py) — same
optima as the paper's CBC solves, minus the 20-hour runtimes. ``agp`` here
is the closed-form-marginal implementation (identical picks); the literal
σ-recomputation variant is timed separately as ``agp_literal`` to show the
runtime separation the paper reports.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import (agp_literal_np, agp_np, egp_np, opt_np, oms_np,
                        qos_matrix_np, rnd_np, sck_np, schedule_value_np,
                        sigma_np, synthetic_instance)

OUT = Path(__file__).resolve().parent.parent / "experiments" / "paper"


def run(trials: int = 10, users=(50, 100, 150, 200, 250), seed0: int = 0,
        literal_agp: bool = True, verbose: bool = True):
    algos = {
        "opt": lambda inst, Q: opt_np(inst, Q),
        "agp": lambda inst, Q: agp_np(inst, Q),
        "egp": lambda inst, Q: egp_np(inst, Q),
        "sck": lambda inst, Q: sck_np(inst, Q),
    }
    if literal_agp:
        algos["agp_literal"] = lambda inst, Q: agp_literal_np(inst, Q)

    rows = []
    for U in users:
        for t in range(trials):
            inst = synthetic_instance(U, seed=seed0 + 1000 * t + U)
            Q = qos_matrix_np(inst)
            vals, times = {}, {}
            for name, fn in algos.items():
                t0 = time.perf_counter()
                x = fn(inst, Q)
                times[name] = time.perf_counter() - t0
                vals[name] = sigma_np(inst, x, Q)
            t0 = time.perf_counter()
            _, y = rnd_np(inst, seed=seed0 + t)
            times["rnd"] = time.perf_counter() - t0
            vals["rnd"] = schedule_value_np(inst, y, Q)
            rows.append({"U": U, "trial": t, "values": vals, "times": times})
            if verbose:
                r = {k: round(v / max(vals["opt"], 1e-9), 3)
                     for k, v in vals.items()}
                print(f"U={U} trial={t}: ratios {r}")

    summary = {}
    for name in list(algos) + ["rnd"]:
        ratios = [r["values"][name] / max(r["values"]["opt"], 1e-9)
                  for r in rows]
        ts = [r["times"][name] for r in rows]
        summary[name] = {"mean_ratio": float(np.mean(ratios)),
                         "min_ratio": float(np.min(ratios)),
                         "mean_time_s": float(np.mean(ts))}
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / "fig3_validation.json").write_text(
        json.dumps({"rows": rows, "summary": summary}, indent=1))
    if verbose:
        print(json.dumps(summary, indent=1))
    return summary


if __name__ == "__main__":
    run()
