"""Benchmark aggregator — one entry per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV rows. --full uses the paper's trial
counts (slow); the default is a reduced-but-faithful pass.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    trials3 = 10 if args.full else 4
    trials4 = 100 if args.full else 3
    trials5 = 100 if args.full else 50

    print("name,us_per_call,derived")

    from benchmarks import fig3_validation, fig4_scale, fig5_realworld
    from benchmarks import kernels_micro, roofline, scenarios

    t0 = time.perf_counter()
    s3 = fig3_validation.run(trials=trials3, verbose=False,
                             literal_agp=args.full)
    dt = (time.perf_counter() - t0) * 1e6 / trials3
    print(f"fig3_validation,{dt:.0f},egp_ratio={s3['egp']['mean_ratio']:.3f}"
          f";agp_ratio={s3['agp']['mean_ratio']:.3f}"
          f";sck_ratio={s3['sck']['mean_ratio']:.3f}"
          f";paper=0.904/0.900/0.607")

    t0 = time.perf_counter()
    s4 = fig4_scale.run(trials=trials4, verbose=False)
    dt = (time.perf_counter() - t0) * 1e6 / trials4
    print(f"fig4_scale,{dt:.0f},egp_over_sck={s4['egp_over_sck']:.2f}"
          f";paper=~1.5x;egp_ratio={s4['egp'].get('mean_ratio', -1):.3f}")

    t0 = time.perf_counter()
    s5 = fig5_realworld.run(trials=trials5, verbose=False)
    dt = (time.perf_counter() - t0) * 1e6 / trials5
    mobile = s5["placements"]["egp"].get("MobileNet", 0)
    total = sum(s5["placements"]["egp"].values())
    print(f"fig5_realworld,{dt:.0f},egp_mobilenet={mobile}/{total}"
          f";paper=exclusively_mobilenet"
          f";qos_egp={s5['mean_qos']['egp']:.3f}")

    sc = scenarios.run(seeds=(0, 1) if not args.full else (0, 1, 2, 3),
                       n_ticks=4 if not args.full else 8, verbose=False)
    # us_per_call is the batched accelerator call itself (incl. compile),
    # not the host-side validation loop scenarios.run also performs.
    dt = sc["batched_s"] * 1e6 / sc["n_instances"]
    dyn = sc["dynamic"]["flash_crowd"]
    print(f"scenario_sweep,{dt:.0f},n={sc['n_instances']}"
          f";scenarios={sc['n_scenarios']}"
          f";max_abs_diff={sc['max_abs_diff']:.1e}"
          f";host_us={sc['host_s'] * 1e6 / sc['n_instances']:.0f}"
          f";hyst_minus_greedy={dyn['hysteresis'] - dyn['greedy']:.1f}")

    for name, us, derived in kernels_micro.run(verbose=False):
        print(f"kernel_{name},{us:.1f},{derived}")

    rows = roofline.build(verbose=False)
    ok_rows = [r for r in rows if "skip" not in r]
    if ok_rows:
        worst = min(ok_rows, key=lambda r: r["roofline_fraction"])
        best = max(ok_rows, key=lambda r: r["roofline_fraction"])
        import numpy as np
        med = float(np.median([r["roofline_fraction"] for r in ok_rows]))
        print(f"roofline_table,0,cells={len(ok_rows)};median_fraction={med:.3f}"
              f";worst={worst['arch']}/{worst['shape']}={worst['roofline_fraction']:.3f}"
              f";best={best['arch']}/{best['shape']}={best['roofline_fraction']:.3f}")
    else:
        print("roofline_table,0,no dry-run artifacts (run repro.launch.dryrun)")


if __name__ == "__main__":
    main()
