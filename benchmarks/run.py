"""Benchmark aggregator — one entry per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full] [--json PATH]

Prints ``name,us_per_call,derived`` CSV rows. --full uses the paper's trial
counts (slow); the default is a reduced-but-faithful pass. --json writes
the same rows as structured JSON (the ``derived`` k=v pairs parsed into
typed fields) under a versioned schema (:data:`BENCH_SCHEMA_VERSION`),
plus the repro.obs metric digests (latency/throughput histogram
summaries) collected while the benchmarks ran — so the BENCH_* perf
trajectory can be captured mechanically (seed: ``BENCH_baseline.json``).

The regression gate and trajectory::

    # run only the fast deterministic rows and diff against the committed
    # baseline: quality fields within tolerance both directions, timings
    # within --max-slowdown; exit 3 on any violation (the CI gate)
    PYTHONPATH=src python -m benchmarks.run \\
        --rows serving_horizon,tuning_fit,obs_overhead \\
        --json /tmp/bench.json --compare BENCH_baseline.json \\
        --max-slowdown 25

    # append this run to the schema-versioned perf trajectory
    PYTHONPATH=src python -m benchmarks.run --rows serving_horizon \\
        --trajectory BENCH_trajectory.jsonl

Comparison semantics live in :func:`repro.obs.slo.compare_bench`: fields
with a timing suffix (``_us``/``_ns``/``_ms``/``_per_s``/``_pct``) are
machine-dependent and only bounded by the slowdown factor; everything
else (ratios, QoS, miss rates) is a deterministic simulation output and
must reproduce within ``atol + rtol*|base|``.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

#: Version stamp of the --json record layout.
BENCH_SCHEMA_VERSION = 1

#: Version stamp of the --trajectory JSONL record layout.
BENCH_TRAJ_SCHEMA_VERSION = 1

#: Row-group names accepted by --rows, in run order ("kernels" expands to
#: the kernel_* micro rows).
ROW_GROUPS = ("fig3_validation", "fig4_scale", "fig5_realworld",
              "serving_horizon", "tuning_fit", "fleet_scaling",
              "scenario_sweep", "placement_scale", "gateway_soak",
              "kernels", "obs_overhead", "obs_request_trace_overhead",
              "roofline_table")


def _parse_derived(derived: str) -> dict:
    """``"a=1.5;b=2/3;paper=~1.5x"`` → typed fields (float where possible)."""
    out = {}
    for part in derived.split(";"):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            out[part] = True
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


class _Emitter:
    """Prints the classic CSV rows and accumulates structured records."""

    def __init__(self):
        self.rows = []

    def __call__(self, name: str, us_per_call: float, derived: str) -> None:
        # one decimal, bare integers unchanged: keeps sub-10us kernel rows
        # meaningful without reformatting the big figure rows
        us = f"{us_per_call:.1f}".rstrip("0").rstrip(".")
        print(f"{name},{us},{derived}")
        self.rows.append({"name": name, "us_per_call": float(us_per_call),
                          "derived": derived,
                          "fields": _parse_derived(derived)})


def _git_rev() -> "str | None":
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=Path(__file__).resolve().parent, capture_output=True,
            text=True, timeout=5).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        return None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as structured JSON")
    ap.add_argument("--rows", default=None,
                    help="comma list of row groups to run (of: "
                         + ",".join(ROW_GROUPS) + "); default: all")
    ap.add_argument("--compare", default=None, metavar="BASELINE",
                    help="diff this run against a baseline --json document "
                         "(repro.obs.slo.compare_bench); exit 3 on any "
                         "regression")
    ap.add_argument("--max-slowdown", type=float, default=4.0,
                    help="--compare: timing fields may not exceed this "
                         "factor of baseline (raise on noisy CI machines)")
    ap.add_argument("--rtol", type=float, default=0.12,
                    help="--compare: relative tolerance on quality fields")
    ap.add_argument("--atol", type=float, default=0.02,
                    help="--compare: absolute tolerance on quality fields")
    ap.add_argument("--trajectory", default=None, metavar="PATH",
                    help="append this run's rows to a schema-versioned "
                         "JSONL trajectory file")
    ap.add_argument("--placement-us", default=None, metavar="U1,U2,...",
                    help="placement_scale: comma list of user counts to "
                         "measure, overriding the mini/full grids (e.g. "
                         "1000000 for a measured 10^6 sparse row)")
    args = ap.parse_args()
    trials3 = 10 if args.full else 4
    trials4 = 100 if args.full else 3
    trials5 = 100 if args.full else 50

    selected = None
    if args.rows is not None:
        selected = {s.strip() for s in args.rows.split(",") if s.strip()}
        unknown = selected - set(ROW_GROUPS)
        if unknown:
            ap.error(f"unknown --rows group(s): {', '.join(sorted(unknown))}"
                     f" (valid: {', '.join(ROW_GROUPS)})")

    def want(group: str) -> bool:
        return selected is None or group in selected

    emit = _Emitter()
    print("name,us_per_call,derived")

    # one tracer across every benchmark: the instrumented hot paths feed
    # its histograms (serving latency, sweep throughput) as a side effect
    from repro import obs
    tracer = obs.enable()

    if want("fig3_validation"):
        from benchmarks import fig3_validation
        t0 = time.perf_counter()
        s3 = fig3_validation.run(trials=trials3, verbose=False,
                                 literal_agp=args.full)
        dt = (time.perf_counter() - t0) * 1e6 / trials3
        emit("fig3_validation", dt,
             f"egp_ratio={s3['egp']['mean_ratio']:.3f}"
             f";agp_ratio={s3['agp']['mean_ratio']:.3f}"
             f";sck_ratio={s3['sck']['mean_ratio']:.3f}"
             f";paper=0.904/0.900/0.607")

    if want("fig4_scale"):
        from benchmarks import fig4_scale
        t0 = time.perf_counter()
        s4 = fig4_scale.run(trials=trials4, verbose=False)
        dt = (time.perf_counter() - t0) * 1e6 / trials4
        emit("fig4_scale", dt,
             f"egp_over_sck={s4['egp_over_sck']:.2f}"
             f";paper=~1.5x;egp_ratio={s4['egp'].get('mean_ratio', -1):.3f}")

    if want("fig5_realworld"):
        from benchmarks import fig5_realworld
        t0 = time.perf_counter()
        s5 = fig5_realworld.run(trials=trials5, verbose=False)
        dt = (time.perf_counter() - t0) * 1e6 / trials5
        mobile = s5["placements"]["egp"].get("MobileNet", 0)
        total = sum(s5["placements"]["egp"].values())
        emit("fig5_realworld", dt,
             f"egp_mobilenet={mobile}/{total}"
             f";paper=exclusively_mobilenet"
             f";qos_egp={s5['mean_qos']['egp']:.3f}")

    if want("serving_horizon"):
        from benchmarks import serving_horizon
        t0 = time.perf_counter()
        sv = serving_horizon.run(
            seeds=(0,) if not args.full else (0, 1, 2, 3),
            n_ticks=3 if not args.full else 6, verbose=False)
        dt = (time.perf_counter() - t0) * 1e6 / sv["n_runs"]
        edf = sv["per_cell"][("flash_crowd", "edf")]
        fcfs = sv["per_cell"][("flash_crowd", "fcfs")]
        steady = sv["per_cell"][("steady", "edf")]
        emit("serving_horizon", dt,
             f"flash_qos_edf={edf['mean_realized_qos']:.4f}"
             f";flash_miss_edf={edf['miss_rate']:.3f}"
             f";flash_miss_fcfs={fcfs['miss_rate']:.3f}"
             f";steady_qos_edf={steady['mean_realized_qos']:.4f}"
             f";dropped={edf['dropped']}")

    if want("tuning_fit"):
        from benchmarks import tuning
        t0 = time.perf_counter()
        tn = tuning.run(seeds=(0,) if not args.full else (0, 1),
                        n_ticks=2 if not args.full else 4, verbose=False)
        dt = (time.perf_counter() - t0) * 1e6 / tn["n_items"]
        flash = tn["table"]["flash_crowd"]
        emit("tuning_fit", dt,
             f"flash_sw={flash['switching_cost']:g}"
             f";flash_stick={flash['stickiness']:g}"
             f";flash_qos={flash['mean_qos']:.4f}"
             f";frontier={tn['frontier_sizes']['flash_crowd']}"
             f";fit_us={tn['fit_s'] * 1e6:.0f}")

    if want("fleet_scaling"):
        from benchmarks import fleet_scaling
        t0 = time.perf_counter()
        fl = fleet_scaling.run(
            worker_counts=(1, 2, 4),
            seeds=(0,) if not args.full else (0, 1, 2, 3),
            n_ticks=2 if not args.full else 4, verbose=False)
        dt = (time.perf_counter() - t0) * 1e6 / max(fl["n_items"], 1)
        per_n = fl["workers"]
        emit("fleet_scaling", dt,
             f"items={fl['n_items']}"
             + "".join(f";w{n}_items_per_s={per_n[n]['items_per_s']:.2f}"
                       for n in sorted(per_n))
             + f";single_items_per_s={fl['single_items_per_s']:.2f}")

    if want("scenario_sweep"):
        from benchmarks import scenarios
        sc = scenarios.run(seeds=(0, 1) if not args.full else (0, 1, 2, 3),
                           n_ticks=4 if not args.full else 8, verbose=False)
        # us_per_call is the engine's chunked accelerator evaluation (incl.
        # compile), not the host-side validation loop scenarios.run also
        # does.
        dt = sc["batched_s"] * 1e6 / sc["n_instances"]
        dyn = sc["dynamic"]["flash_crowd"]
        emit("scenario_sweep", dt,
             f"n={sc['n_instances']}"
             f";scenarios={sc['n_scenarios']}"
             f";max_abs_diff={sc['max_abs_diff']:.1e}"
             f";host_us={sc['host_s'] * 1e6 / sc['n_instances']:.0f}"
             f";hyst_minus_greedy={dyn['hysteresis'] - dyn['greedy']:.1f}")

    if want("placement_scale"):
        from benchmarks import placement_scale
        ps_us = (1000, 10_000, 100_000) if args.full else (1000,)
        if args.placement_us:
            ps_us = tuple(int(s) for s in args.placement_us.split(",")
                          if s.strip())
        t0 = time.perf_counter()
        ps = placement_scale.run(us=ps_us, verbose=False)
        dt = (time.perf_counter() - t0) * 1e6 / len(ps_us)
        parts = []
        for lbl, rec in ps["per_u"].items():
            parts.append(f"sparse_{lbl}_ms={rec['sparse_ms']:.2f}")
            if "dense_ms" in rec:
                parts.append(f"dense_{lbl}_ms={rec['dense_ms']:.2f}")
            parts.append(f"mem_ratio_{lbl}={rec['mem_ratio']:.0f}")
            # speedup is a ratio of two timings — machine-dependent, so it
            # only goes into --full rows (the trajectory), never the mini
            # row the CI --compare gate checks as a quality field
            if args.full and "speedup" in rec:
                parts.append(f"speedup_{lbl}={rec['speedup']:.1f}")
        if "u1000k" not in ps["per_u"]:
            # the 10^6 cell's memory story stays in the mini gate even
            # when the cell isn't run: the bytes models are exact given
            # the catalog shape (P, k), which any measured U pins down —
            # the measured 10^6 row itself lives in the trajectory
            # (--placement-us 1000000)
            r0 = next(iter(ps["per_u"].values()))
            u6, e6 = 1_000_000, max(10, 1_000_000 // 1000)
            ratio6 = (placement_scale.dense_bytes(u6, r0["P"], e6)
                      / placement_scale.sparse_bytes(u6, r0["P"], e6,
                                                     r0["k"]))
            parts.append(f"mem_ratio_u1000k={ratio6:.0f}")
        if ps["rel_diff_paper"] is not None:
            parts.append(f"rel_diff_paper={ps['rel_diff_paper']:.2e}")
        bm = ps.get("bucket_mix")
        if bm:
            parts.append(f"bucketed_mix_ms={bm['bucket_ms']:.2f}"
                         f";global_pad_ms={bm['global_ms']:.2f}"
                         f";pad_waste_pct={bm['pad_waste'] * 100:.1f}")
        emit("placement_scale", dt, ";".join(parts))

    if want("gateway_soak"):
        from benchmarks import gateway_soak
        t0 = time.perf_counter()
        gs = gateway_soak.run(full=args.full, verbose=False)
        dt = (time.perf_counter() - t0) * 1e6 / max(gs["ticks"], 1)
        # ticks / bounded / drops / admitted fraction are the soak's
        # operational invariants (quality fields); throughput and the
        # latency quantiles are machine speed (timing suffixes)
        emit("gateway_soak", dt,
             f"ticks={gs['ticks']}"
             f";bounded={int(gs['bounded'])}"
             f";ok={int(gs['ok'])}"
             f";dropped={gs['dropped_ingress']}"
             f";admitted_frac={gs['admitted'] / max(gs['sent'], 1):.3f}"
             f";admitted_per_s={gs['sustained_rps']:.1f}"
             f";p99_admission_ms={gs['p99_admission_ms']:.2f}"
             f";p99_lag_ms={gs['p99_loop_lag_ms']:.2f}")

    if want("kernels"):
        from benchmarks import kernels_micro
        for name, us, derived in kernels_micro.run(verbose=False):
            emit(f"kernel_{name}", us, derived)

    if want("obs_overhead"):
        from benchmarks import serving_horizon
        ov = serving_horizon.obs_overhead()
        emit("obs_overhead", ov["noop_span_ns"] / 1e3,
             f"disabled_pct={ov['disabled_pct']:.4f}"
             f";enabled_pct={ov['enabled_pct']:.2f}"
             f";events={ov['n_events']}"
             f";noop_span_ns={ov['noop_span_ns']:.0f}")

    if want("obs_request_trace_overhead"):
        from benchmarks import serving_horizon
        ov = serving_horizon.reqtrace_overhead()
        # `kept` is deterministic for the fixed (config, seed, sampling)
        # — the quality field; the rest is machine speed
        emit("obs_request_trace_overhead", ov["disabled_noop_ns"] / 1e3,
             f"kept={ov['kept']}"
             f";disabled_noop_ns={ov['disabled_noop_ns']:.0f}"
             f";enabled_sampled_pct={ov['enabled_sampled_pct']:.2f}")

    if want("roofline_table"):
        from benchmarks import roofline
        rows = roofline.build(verbose=False)
        # analytic placement rows carry no roofline_fraction — keep them
        # out of the HLO-derived aggregate
        ok_rows = [r for r in rows
                   if "skip" not in r and "roofline_fraction" in r]
        if ok_rows:
            worst = min(ok_rows, key=lambda r: r["roofline_fraction"])
            best = max(ok_rows, key=lambda r: r["roofline_fraction"])
            import numpy as np
            med = float(np.median([r["roofline_fraction"]
                                   for r in ok_rows]))
            emit("roofline_table", 0,
                 f"cells={len(ok_rows)};median_fraction={med:.3f}"
                 f";worst={worst['arch']}/{worst['shape']}"
                 f"={worst['roofline_fraction']:.3f}"
                 f";best={best['arch']}/{best['shape']}"
                 f"={best['roofline_fraction']:.3f}")
        else:
            emit("roofline_table", 0,
                 "no_dryrun_artifacts=1;hint=run repro.launch.dryrun")

    doc = {
        "bench_schema": BENCH_SCHEMA_VERSION,
        "full": bool(args.full),
        "rows": emit.rows,
        "obs": {
            "histograms": tracer.metrics.histograms(),
            "counters": dict(tracer.counters),
            "n_spans": tracer.n_spans,
        },
    }
    if args.json:
        path = Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(doc, indent=1))

    if args.trajectory:
        path = Path(args.trajectory)
        path.parent.mkdir(parents=True, exist_ok=True)
        rec = {
            "bench_traj_schema": BENCH_TRAJ_SCHEMA_VERSION,
            "t": round(time.time(), 3),
            "git_rev": _git_rev(),
            "full": bool(args.full),
            "rows": [{"name": r["name"], "us_per_call": r["us_per_call"],
                      "fields": r["fields"]} for r in emit.rows],
        }
        with path.open("a") as fh:
            fh.write(json.dumps(rec, separators=(",", ":"),
                                sort_keys=True) + "\n")
        print(f"[bench] appended {len(emit.rows)} row(s) to {path}",
              file=sys.stderr)

    rc = 0
    if args.compare:
        from repro.obs.slo import compare_bench
        base = json.loads(Path(args.compare).read_text())
        have = int(base.get("bench_schema", -1))
        if have != BENCH_SCHEMA_VERSION:
            print(f"[bench] baseline {args.compare} has bench_schema "
                  f"v{have}, this code writes v{BENCH_SCHEMA_VERSION}",
                  file=sys.stderr)
            rc = 3
        else:
            cmp_rows = None
            if selected is not None:
                cmp_rows = set()
                for group in selected:
                    if group == "kernels":
                        cmp_rows |= {r["name"] for r in emit.rows
                                     if r["name"].startswith("kernel_")}
                    else:
                        cmp_rows.add(group)
            res = compare_bench(doc, base, max_slowdown=args.max_slowdown,
                                rtol=args.rtol, atol=args.atol,
                                rows=cmp_rows)
            if res["violations"]:
                print(f"[bench] REGRESSION vs {args.compare} "
                      f"({len(res['violations'])} violation(s) over "
                      f"{len(res['rows_checked'])} row(s)):",
                      file=sys.stderr)
                for v in res["violations"]:
                    print(f"  {v}", file=sys.stderr)
                rc = 3
            else:
                print(f"[bench] no regression vs {args.compare}: "
                      f"{len(res['rows_checked'])} row(s), "
                      f"{res['fields_checked']} field(s) checked",
                      file=sys.stderr)
    obs.disable()
    return rc


if __name__ == "__main__":
    sys.exit(main())
