"""Fleet scaling benchmark — items/s at 1, 2, 4 workers on one grid.

For each worker count the same small ``kind="serving"`` grid is planned
into a fresh fleet root, drained by N forked local workers
(:func:`repro.fleet.spawn_local_workers` — real subprocesses, so the
measurement includes dispatch/claim/merge overhead, exactly what a
multi-host deployment pays), merged, and verified complete; the reported
rate is items per second of end-to-end wall clock. The ``fleet_scaling``
row of ``benchmarks/run.py``.

Serving horizons are host-side event-loop work, so scaling is ~linear
until task granularity (one seed's horizon) starves the queue; the
benchmark also reports the single-process engine rate as the 0-overhead
baseline.

    PYTHONPATH=src python -m benchmarks.fleet_scaling
"""
from __future__ import annotations

import tempfile
import time
from pathlib import Path
from typing import Dict, Sequence

from repro.fleet import merge, plan, reap, run_worker, spawn_local_workers
from repro.sweeps import SweepSpec, SweepStore, run_sweep

#: Shrunk scenario (see tests/test_horizon.py) — keeps horizons fast.
SMALL = {"n_user_slots": 32, "n_services": 8, "max_impls": 3, "n_edges": 4}


def _spec(seeds: Sequence[int], n_ticks: int) -> SweepSpec:
    grid = tuple(
        tuple(sorted({**SMALL, "switching_cost": sc,
                      "stickiness": st}.items()))
        for sc, st in ((0.0, 0.0), (2.0, 3.0)))
    return SweepSpec(kind="serving", scenarios=("steady", "flash_crowd"),
                     seeds=tuple(seeds), n_ticks=n_ticks,
                     algos=("edf",), override_grid=grid)


def run(worker_counts: Sequence[int] = (1, 2, 4),
        seeds: Sequence[int] = (0, 1, 2, 3), n_ticks: int = 2,
        verbose: bool = True) -> Dict:
    spec = _spec(seeds, n_ticks)
    n_items = len(spec.expand())
    out: Dict = {"n_items": n_items, "workers": {}}

    with tempfile.TemporaryDirectory(prefix="fleet_bench_") as tmp:
        tmp = Path(tmp)
        # 0-overhead baseline: the single-process engine
        t0 = time.perf_counter()
        run_sweep(_spec(seeds, n_ticks), store_dir=tmp / "single")
        single_s = time.perf_counter() - t0
        out["single_process_s"] = single_s
        out["single_items_per_s"] = n_items / single_s

        for n in worker_counts:
            root, store = tmp / f"fleet_{n}", tmp / f"store_{n}"
            t0 = time.perf_counter()
            plan(spec, root, target_store=store)
            if n <= 1:
                run_worker(root, owner="bench-0")
            else:
                procs = spawn_local_workers(root, n, silence=True)
                for p in procs:
                    p.wait()
                reap(root)
                run_worker(root, owner="bench-mopup")  # cover stragglers
            mg = merge(root, store)
            wall = time.perf_counter() - t0
            assert mg.get("missing_items") == 0, mg
            assert len(SweepStore(store)) == n_items
            out["workers"][n] = {"wall_s": wall,
                                 "items_per_s": n_items / wall}
            if verbose:
                print(f"[fleet_scaling] {n} worker(s): {n_items} items in "
                      f"{wall:.2f}s = {n_items / wall:.1f} items/s",
                      flush=True)
    if verbose:
        print(f"[fleet_scaling] single-process engine: "
              f"{out['single_items_per_s']:.1f} items/s")
    return out


if __name__ == "__main__":
    run()
