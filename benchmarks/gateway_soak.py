"""Gateway soak benchmark: the live control plane under sustained load.

Wraps :func:`repro.gateway.soak.run_soak` as a ``benchmarks/run.py`` row:
an open-loop trace replay is wired into a wall-clock gateway inside one
event loop and run for a fixed wall budget at a multiple of the trace's
native request rate. The row's quality fields are the *operational
invariants* (tick count, bounded backlog, no ingress drops, admitted
fraction); sustained RPS and the p99 admission / loop-lag latencies are
machine-dependent and carry timing suffixes so the CI ``--compare`` gate
bounds them by ``--max-slowdown`` only.

* **mini** (CI gate): a shrunk ``flash_crowd`` catalog at 20× for ~2 s —
  measures control-plane overhead, not placement scale, and keeps the
  gate fast.
* **full**: the ISSUE acceptance bar — ``trace_replay_bursty`` at 10×
  its native rate for 30 s wall-clock.
"""
from __future__ import annotations

from typing import Dict

#: Shrunk catalog for the mini row — the same small-instance family the
#: tier-1 gateway tests use, so pass/fail tracks the control plane.
MINI_OVERRIDES = {
    "n_user_slots": 32, "n_services": 8, "max_impls": 3, "n_edges": 4,
    "prompt_tokens": 768, "new_tokens": 64, "max_batch": 4,
}


def run(*, full: bool = False, seed: int = 0,
        verbose: bool = True) -> Dict:
    """One judged soak; returns ``SoakReport.to_json()`` plus the knobs."""
    from repro.gateway import run_soak

    if full:
        report = run_soak("trace_replay_bursty", seed=seed,
                          policy="feedback", speed=10.0, duration_s=30.0)
    else:
        report = run_soak("flash_crowd", seed=seed, policy="feedback",
                          speed=20.0, duration_s=2.0,
                          overrides=dict(MINI_OVERRIDES))
    if verbose:
        print(report.line(), flush=True)
    return report.to_json()


if __name__ == "__main__":
    run(verbose=True)
