"""Serving-horizon benchmark — realized QoS through the full engine.

Drives scenario traffic end-to-end (placement with hysteresis → OMS
routing → stateful continuous batching, :mod:`repro.serving.horizon`) and
reports *realized* QoS and deadline-miss rate per (scenario, policy) for
the QoS-aware EDF queue against the FCFS baseline — the §VI-C
realized-vs-expected view under synthetic scenario traffic. The load
point (long prompts, small batches) is chosen so executors actually
congest; an idle engine shows no policy separation.

    PYTHONPATH=src python -m benchmarks.serving_horizon
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from repro.serving.horizon import HorizonConfig, run_horizon

#: Congested-but-fast load point (see tests/test_horizon.py::LOAD).
LOAD = dict(prompt_tokens=768, new_tokens=64, max_batch=4)


def run(scenarios: Sequence[str] = ("steady", "flash_crowd"),
        policies: Sequence[str] = ("edf", "fcfs"),
        seeds: Sequence[int] = (0, 1), n_ticks: int = 4,
        verbose: bool = True) -> Dict:
    out: Dict = {"per_cell": {}, "n_runs": 0}
    for scenario in scenarios:
        for policy in policies:
            qos, miss, served, dropped = [], [], 0, 0
            for seed in seeds:
                res = run_horizon(HorizonConfig(
                    scenario=scenario, policy=policy, seed=seed,
                    n_ticks=n_ticks, **LOAD))
                qos.append(res.mean_realized_qos)
                miss.append(res.miss_rate)
                served += res.served
                dropped += res.dropped
                out["n_runs"] += 1
            cell = {"mean_realized_qos": float(np.mean(qos)),
                    "miss_rate": float(np.mean(miss)),
                    "served": served, "dropped": dropped}
            out["per_cell"][(scenario, policy)] = cell
            if verbose:
                print(f"[serving] {scenario:<14} {policy:<5} "
                      f"qos={cell['mean_realized_qos']:.4f} "
                      f"miss={cell['miss_rate']:.3f} "
                      f"served={served} dropped={dropped}")
    return out


if __name__ == "__main__":
    run()
