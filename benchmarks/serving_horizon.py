"""Serving-horizon benchmark — realized QoS through the full engine.

Drives scenario traffic end-to-end (placement with hysteresis → OMS
routing → stateful continuous batching, :mod:`repro.serving.horizon`) and
reports *realized* QoS and deadline-miss rate per (scenario, policy) for
the QoS-aware EDF queue against the FCFS baseline — the §VI-C
realized-vs-expected view under synthetic scenario traffic. The load
point (long prompts, small batches) is chosen so executors actually
congest; an idle engine shows no policy separation.

    PYTHONPATH=src python -m benchmarks.serving_horizon
"""
from __future__ import annotations

import time
from typing import Dict, Sequence

import numpy as np

from repro import obs
from repro.obs import trace as _obs_trace
from repro.serving.horizon import HorizonConfig, run_horizon

#: Congested-but-fast load point (see tests/test_horizon.py::LOAD).
LOAD = dict(prompt_tokens=768, new_tokens=64, max_batch=4)


def obs_overhead(scenario: str = "steady", policy: str = "edf",
                 seed: int = 0, n_ticks: int = 3) -> Dict:
    """Measure the cost of the obs instrumentation on one horizon run.

    Two numbers, both against the same config:

    * ``disabled_pct`` — the *disabled* fast path: per-call cost of a
      no-op ``obs.span`` (measured) times the number of span/gauge events
      one traced run records, as a fraction of the untraced wall time.
      This is the overhead every un-instrumented user pays; the repo's
      contract keeps it under a few percent.
    * ``enabled_pct`` — wall-time delta of a fully traced run vs the
      untraced run (noisy on a busy host; informational).
    """
    prev = _obs_trace._TRACER
    _obs_trace._TRACER = None
    cfg = HorizonConfig(scenario=scenario, policy=policy, seed=seed,
                        n_ticks=n_ticks, **LOAD)
    try:
        run_horizon(cfg)  # warmup (imports, jit, caches)
        t0 = time.perf_counter()
        run_horizon(cfg)
        disabled_s = time.perf_counter() - t0

        # no-op span cost: median-of-reps of a tight loop
        reps = []
        for _ in range(5):
            t0 = time.perf_counter()
            for _ in range(10_000):
                with obs.span("x"):
                    pass
            reps.append((time.perf_counter() - t0) / 10_000)
        noop_s = float(np.median(reps))

        tr = obs.enable()
        t0 = time.perf_counter()
        run_horizon(cfg)
        enabled_s = time.perf_counter() - t0
        n_events = tr.n_spans + tr._n_gauges + len(tr.counters)
    finally:
        _obs_trace._TRACER = prev
    return {
        "disabled_s": disabled_s,
        "enabled_s": enabled_s,
        "noop_span_ns": noop_s * 1e9,
        "n_events": int(n_events),
        "disabled_pct": 100.0 * n_events * noop_s / disabled_s,
        "enabled_pct": 100.0 * (enabled_s - disabled_s) / disabled_s,
    }


def reqtrace_overhead(scenario: str = "steady", policy: str = "edf",
                      seed: int = 0, n_ticks: int = 3,
                      sample_every: int = 16) -> Dict:
    """Measure the cost of per-request causal tracing (repro.obs v3).

    Mirrors :func:`obs_overhead`:

    * ``disabled_noop_ns`` — per-call cost of the disabled hook (one
      module-global load + ``is None`` check), measured on a tight loop.
      This must stay within the PR-6 span budget (~0.25 µs).
    * ``enabled_sampled_pct`` — wall-time delta of a horizon run with
      tracing + decision ledger on (1-in-``sample_every`` sampling) vs
      off (noisy on a busy host; informational).
    * ``kept`` — number of sampled traces; deterministic for a fixed
      (config, seed, sample_every), so it doubles as the regression
      quality signal.
    """
    from repro.obs import ledger as _obs_ledger
    from repro.obs import reqtrace as _obs_reqtrace

    prev_rt = _obs_reqtrace._REQTRACER
    prev_led = _obs_ledger._LEDGER
    _obs_reqtrace._REQTRACER = None
    _obs_ledger._LEDGER = None
    cfg = HorizonConfig(scenario=scenario, policy=policy, seed=seed,
                        n_ticks=n_ticks, **LOAD)
    try:
        run_horizon(cfg)  # warmup (imports, jit, caches)
        t0 = time.perf_counter()
        run_horizon(cfg)
        disabled_s = time.perf_counter() - t0

        # disabled-hook cost: the exact expression every hot-path call
        # site evaluates when tracing is off
        reps = []
        for _ in range(5):
            t0 = time.perf_counter()
            for _ in range(10_000):
                rt = _obs_reqtrace._REQTRACER
                if rt is not None:  # pragma: no cover — rt is None here
                    rt.event(0, "receipt", 0.0)
            reps.append((time.perf_counter() - t0) / 10_000)
        noop_s = float(np.median(reps))

        rt = _obs_reqtrace.enable_request_tracing(
            sample_every=sample_every)
        _obs_ledger.enable_ledger()
        t0 = time.perf_counter()
        run_horizon(cfg)
        enabled_s = time.perf_counter() - t0
        kept = len(rt.kept())
    finally:
        _obs_reqtrace._REQTRACER = prev_rt
        _obs_ledger._LEDGER = prev_led
    return {
        "disabled_s": disabled_s,
        "enabled_s": enabled_s,
        "disabled_noop_ns": noop_s * 1e9,
        "kept": int(kept),
        "enabled_sampled_pct":
            100.0 * (enabled_s - disabled_s) / disabled_s,
    }


def run(scenarios: Sequence[str] = ("steady", "flash_crowd"),
        policies: Sequence[str] = ("edf", "fcfs"),
        seeds: Sequence[int] = (0, 1), n_ticks: int = 4,
        verbose: bool = True) -> Dict:
    out: Dict = {"per_cell": {}, "n_runs": 0}
    for scenario in scenarios:
        for policy in policies:
            qos, miss, served, dropped = [], [], 0, 0
            for seed in seeds:
                res = run_horizon(HorizonConfig(
                    scenario=scenario, policy=policy, seed=seed,
                    n_ticks=n_ticks, **LOAD))
                qos.append(res.mean_realized_qos)
                miss.append(res.miss_rate)
                served += res.served
                dropped += res.dropped
                out["n_runs"] += 1
            cell = {"mean_realized_qos": float(np.mean(qos)),
                    "miss_rate": float(np.mean(miss)),
                    "served": served, "dropped": dropped}
            out["per_cell"][(scenario, policy)] = cell
            if verbose:
                print(f"[serving] {scenario:<14} {policy:<5} "
                      f"qos={cell['mean_realized_qos']:.4f} "
                      f"miss={cell['miss_rate']:.3f} "
                      f"served={served} dropped={dropped}")
    return out


if __name__ == "__main__":
    run()
    ov = obs_overhead()
    print(f"[serving] obs overhead: disabled {ov['disabled_pct']:.3f}% "
          f"({ov['noop_span_ns']:.0f}ns/span x {ov['n_events']} events), "
          f"enabled {ov['enabled_pct']:+.1f}%")
